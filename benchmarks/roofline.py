"""Roofline analysis from the dry-run artifacts (deliverable g).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ICI ~50 GB/s
per link; we credit 2 links per chip for a 2D-torus axis -> 100 GB/s/chip
aggregate collective bandwidth.  All inputs are PER-DEVICE quantities from
the trip-count-weighted HLO analysis (see repro/launch/hlo_analysis.py):

  compute_term    = dot_flops / 197e12            (s)
  memory_term     = tpu_bytes / 819e9             (s) where tpu_bytes counts
                    dot/gather/scatter/DUS/copy/collective I/O only --
                    i.e. assumes XLA-TPU fuses every elementwise chain into
                    its neighbors.  Two brackets are reported alongside:
                    hbm_bytes (CPU-fusion granularity, upper bound) and
                    model_min_bytes (weights+states+caches, lower bound).
  collective_term = collective_bytes / 100e9      (s)

MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (prefill) /
2 N_active B (decode), D = global tokens per step.  The useful-compute
fraction MODEL_FLOPS / (chips * dot_flops) exposes remat/dispatch/causal
overheads; roofline_fraction = useful_compute_time / max(term) is the
score per cell.
"""
from __future__ import annotations

import glob
import json
import os
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 100e9          # 2 x 50 GB/s links per torus axis

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


def model_flops(arch: str, shape_name: str) -> float:
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.models import build
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    api = build(cfg)
    n = api.num_active_params
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * b * s
    if shape.kind == "prefill":
        return 2.0 * n * b * s
    return 2.0 * n * b          # decode: one token per sequence


def min_hbm_bytes(arch: str, shape_name: str, chips: int) -> float:
    """Lower bound on per-device HBM traffic per step."""
    from repro.configs import ARCHS, SHAPES_BY_NAME
    from repro.models import build, input_specs
    import math
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    api = build(cfg)
    n, n_act = api.num_params, api.num_active_params
    if shape.kind == "train":
        # bf16 weight reads fwd+bwd+remat-fwd (3x active) + fp32 AdamW
        # state read/write (16 B/param r+w -> 32) spread over all chips
        return (3 * 2 * n_act + 32 * n) / chips
    if shape.kind == "prefill":
        return 2 * n_act / chips
    _, cache = input_specs(cfg, shape)
    cache_bytes = sum(math.prod(l.shape) * l.dtype.itemsize
                      for l in __import__("jax").tree.leaves(cache))
    return (2 * n_act + cache_bytes) / chips


def analyze_cell(rec: dict) -> dict:
    chips = rec["n_devices"]
    a = rec["analyzed"]
    compute = a["dot_flops"] / PEAK_FLOPS
    memory = a.get("tpu_bytes", a["hbm_bytes"]) / HBM_BW
    collective = a["collective_bytes"] / ICI_BW
    terms = {"compute": compute, "memory": memory,
             "collective": collective}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful_time = mf / chips / PEAK_FLOPS
    bound = max(terms.values())
    minb = min_hbm_bytes(rec["arch"], rec["shape"], chips)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "compute_s": compute, "memory_s": memory,
        "collective_s": collective, "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": mf / max(1.0, a["dot_flops"] * chips),
        "roofline_fraction": useful_time / max(bound, 1e-12),
        "memory_upper_s": a["hbm_bytes"] / HBM_BW,
        "memory_lower_s": minb / HBM_BW,
        "bytes_by_op": a.get("bytes_by_op", {}),
        "hbm_utilization_lower": minb / max(
            1.0, a.get("tpu_bytes", a["hbm_bytes"])),
        "mem_per_device_gib": (rec["memory"]["argument_bytes"]
                               + rec["memory"]["temp_bytes"]) / 2**30,
        "collectives": a["collectives"],
    }


def note(r: dict) -> str:
    d = r["dominant"]
    if d == "compute":
        gap = 1 - r["useful_flops_ratio"]
        return (f"compute-bound; {gap:.0%} of dot flops are overhead "
                "(remat/causal-waste/dispatch) - cut those to move the term")
    if d == "memory":
        return ("memory-bound; HLO traffic is "
                f"{1 / max(r['hbm_utilization_lower'], 1e-9):.0f}x the "
                "weight+state lower bound - fuse elementwise chains / "
                "larger per-core batch")
    return ("collective-bound; shrink FSDP all-gathers (bf16 gathers, "
            "wider TP) or overlap with compute")


def load_records(mesh: str = "single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(ARTIFACTS, "dryrun",
                                              f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "error" not in rec:
            recs.append(rec)
    return recs


def spinner_kernel_rows(quick: bool = False) -> list:
    """Roofline model of the Spinner vertex update, fused vs. split.

    For each (graph, k) cell: the autotuner's tile choice, the REAL padded
    edge geometry from ``build_tiled_csr``, and the modeled HBM traffic of
    the split path (score matrix written by the kernel, re-read by the XLA
    normalize/argmax chain) against the fused megakernel (score block
    VMEM-resident; the 2 * V_pad * k_pad bytes disappear).  Writes
    ``artifacts/roofline_spinner.md``.
    """
    from repro.core import generators
    from repro.core.graph import build_tiled_csr
    from repro.kernels import autotune

    cells = [("ws", generators.watts_strogatz(
                 2000 if quick else 20_000, 8, 0.2, seed=0)),
             ("powerlaw", generators.powerlaw_ba(
                 2000 if quick else 20_000, 8, seed=0))]
    rows = []
    table = ["| graph | k | tile (v,e) | split B/edge | fused B/edge "
             "| removed V*k MiB | compute s | mem s (fused) | dominant |",
             "|---|---|---|---|---|---|---|---|---|"]
    for name, g in cells:
        for k in (16, 64) if quick else (16, 64, 256):
            tile_v, tile_e, k_pad = autotune.choose_tile_config(g, k)
            tiled = build_tiled_csr(g, tile_v=tile_v, tile_e=tile_e)
            e_pad = tiled.num_tiles * tiled.max_chunks * tiled.tile_e
            split, fused = autotune.modeled_traffic(tiled.padded_v, e_pad,
                                                    k_pad)
            s_b, f_b = sum(split.values()), sum(fused.values())
            removed = split["score_write"] + split["score_read"]
            assert s_b - f_b == removed      # exactly the V*k round-trip
            flops = 2.0 * e_pad * (tile_v + k_pad)
            compute = flops / PEAK_FLOPS
            mem_f, mem_s = f_b / HBM_BW, s_b / HBM_BW
            dominant = "compute" if compute > mem_f else "memory"
            n_edges = 2 * g.num_undirected_edges
            rows.append({
                "name": f"roofline/spinner/{name}/k{k}",
                "us_per_call": max(compute, mem_f) * 1e6,
                "derived": f"tile=({tile_v},{tile_e},{k_pad});"
                           f"split_Bpe={s_b / n_edges:.1f};"
                           f"fused_Bpe={f_b / n_edges:.1f};"
                           f"removed_bytes={removed:.0f};"
                           f"dominant={dominant}",
                "graph": name, "k": k,
                "tile_config": (tile_v, tile_e, k_pad),
                "split_bytes": s_b, "fused_bytes": f_b,
                "removed_bytes": removed, "compute_s": compute,
                "memory_s_fused": mem_f, "memory_s_split": mem_s,
                "dominant": dominant,
            })
            table.append(
                f"| {name} | {k} | ({tile_v},{tile_e}) "
                f"| {s_b / n_edges:.1f} | {f_b / n_edges:.1f} "
                f"| {removed / 2**20:.1f} | {compute:.2e} "
                f"| {mem_f:.2e} | {dominant} |")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "roofline_spinner.md"), "w") as f:
        f.write("\n".join(table) + "\n")
    return rows


def run(quick: bool = False) -> list:
    rows = spinner_kernel_rows(quick)
    table_md = ["| arch | shape | compute s | memory s | coll s | dominant "
                "| useful/dot | roofline frac |",
                "|---|---|---|---|---|---|---|---|"]
    for rec in load_records("single"):
        r = analyze_cell(rec)
        r["note"] = note(r)
        rows.append({
            "name": f"roofline/{r['arch']}/{r['shape']}",
            "us_per_call": max(r["compute_s"], r["memory_s"],
                               r["collective_s"]) * 1e6,
            "derived": f"dominant={r['dominant']};"
                       f"roofline_frac={r['roofline_fraction']:.3f};"
                       f"useful_ratio={r['useful_flops_ratio']:.2f}",
            **r,
        })
        table_md.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} "
            f"| {r['memory_s']:.3f} | {r['collective_s']:.3f} "
            f"| **{r['dominant']}** | {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.3f} |")
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, "roofline.md"), "w") as f:
        f.write("\n".join(table_md) + "\n")
    with open(os.path.join(ARTIFACTS, "roofline.json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for r in rows:
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
    return rows


if __name__ == "__main__":
    run()
