"""Spinner-scores Pallas kernel: interpret-mode validation timing + the
static VMEM/roofline accounting of the kernel itself (TPU-target numbers).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import generators
from repro.core.graph import build_tiled_csr
from repro.kernels import ops, ref

from .common import emit


def run(quick: bool = False) -> list:
    rows = []
    g = generators.powerlaw_ba(3000 if quick else 20_000, 8, seed=0)
    for k, tile in ((16, 128), (64, 128), (256, 128)):
        tiled = build_tiled_csr(g, tile_v=tile, tile_e=tile)
        labels = jnp.asarray(
            np.random.default_rng(0).integers(0, k, g.num_vertices),
            jnp.int32)
        out = ops.spinner_scores_tiled(labels, tiled=tiled, k=k)
        expect = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                        jnp.asarray(g.dst),
                                        jnp.asarray(g.weight),
                                        g.num_vertices, k)
        err = float(jnp.abs(out - expect).max())
        # ref-path timing (the XLA scatter-add production path on CPU)
        f = jax.jit(lambda lab: ref.spinner_scores_ref(
            lab, jnp.asarray(g.src), jnp.asarray(g.dst),
            jnp.asarray(g.weight), g.num_vertices, k))
        f(labels).block_until_ready()
        t0 = time.time()
        f(labels).block_until_ready()
        dt = time.time() - t0
        # static kernel accounting for the TPU target
        k_pad = ops.round_up(k, 128)
        e_pad = tiled.num_tiles * tiled.max_chunks * tiled.tile_e
        vmem = (tile * tiled.tile_e + tiled.tile_e * k_pad
                + tile * k_pad) * 4
        mxu_flops = 2 * e_pad * (tile + k_pad)
        hbm = e_pad * (4 + 4 + 4) + tiled.padded_v * k_pad * 4
        rows.append({
            "name": f"kernel/spinner_scores/k{k}",
            "us_per_call": dt * 1e6,
            "derived": f"max_err={err:.1e};vmem_bytes={vmem};"
                       f"pad_overhead={e_pad / (2 * g.num_undirected_edges):.2f};"
                       f"arith_intensity={mxu_flops / hbm:.1f}",
            "err": err, "vmem": vmem, "e_pad": e_pad,
        })

    # end-to-end: both score backends driven by the fused on-device engine
    # (interpret-mode Pallas is host-speed; the row validates the plumbing
    # and gives the XLA-backend steady-state number)
    from repro.core import EngineOptions, SpinnerConfig, partition
    g_small = generators.powerlaw_ba(1000 if quick else 3000, 6, seed=1)
    for backend in ("xla",) if quick else ("xla", "pallas"):
        cfg = SpinnerConfig(k=16, seed=0, max_iters=30)
        opts = EngineOptions(score_backend=backend)
        partition(g_small, cfg, record_history=False,
                  engine="fused", options=opts)       # compile
        t0 = time.time()
        res = partition(g_small, cfg, record_history=False, engine="fused",
                        options=opts)
        dt = time.time() - t0
        rows.append({
            "name": f"kernel/fused_engine/{backend}",
            "us_per_call": dt * 1e6 / max(1, res.iterations),
            "derived": f"iters={res.iterations};total_s={dt:.3f};"
                       f"backend={backend}",
        })
    emit(rows, "bench_kernel")
    return rows


if __name__ == "__main__":
    run()
