"""Spinner-scores Pallas kernel: interpret-mode validation timing + the
static VMEM/roofline accounting of the kernel itself (TPU-target numbers).

Tile configs come from the autotuner (``repro.kernels.autotune``), not a
hardcoded sweep, so each row reports the shape the engine would actually
bind; the modeled-traffic columns quantify the fused megakernel's HBM
win (the (V_pad, k_pad) score write+read the split path pays).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import generators
from repro.core.graph import build_tiled_csr
from repro.kernels import autotune, ops, ref

from .common import emit


def run(quick: bool = False) -> list:
    rows = []
    g = generators.powerlaw_ba(3000 if quick else 20_000, 8, seed=0)
    for k in (16, 64, 256):
        tile_v, tile_e, k_pad = autotune.choose_tile_config(g, k)
        tiled = build_tiled_csr(g, tile_v=tile_v, tile_e=tile_e)
        labels = jnp.asarray(
            np.random.default_rng(0).integers(0, k, g.num_vertices),
            jnp.int32)
        out = ops.spinner_scores_tiled(labels, tiled=tiled, k=k)
        expect = ref.spinner_scores_ref(labels, jnp.asarray(g.src),
                                        jnp.asarray(g.dst),
                                        jnp.asarray(g.weight),
                                        g.num_vertices, k)
        err = float(jnp.abs(out - expect).max())
        # ref-path timing (the XLA scatter-add production path on CPU)
        f = jax.jit(lambda lab: ref.spinner_scores_ref(
            lab, jnp.asarray(g.src), jnp.asarray(g.dst),
            jnp.asarray(g.weight), g.num_vertices, k))
        f(labels).block_until_ready()
        t0 = time.time()
        f(labels).block_until_ready()
        dt = time.time() - t0
        # static kernel accounting for the TPU target: the (tile_v, k_pad)
        # accumulator stays VMEM-resident across ALL chunk revisits of its
        # tile, on top of the double-buffered edge blocks and the two
        # one-hot matmul operands
        e_pad = tiled.num_tiles * tiled.max_chunks * tiled.tile_e
        vmem = (tile_v * k_pad                 # persistent accumulator
                + 2 * 3 * tile_e               # double-buffered edge chunk
                + tile_e * tile_v              # one-hot src operand
                + tile_e * k_pad) * 4          # one-hot label operand
        mxu_flops = 2 * e_pad * (tile_v + k_pad)
        split, fused = autotune.modeled_traffic(tiled.padded_v, e_pad,
                                                k_pad)
        s_bytes, f_bytes = sum(split.values()), sum(fused.values())
        n_edges = 2 * g.num_undirected_edges
        rows.append({
            "name": f"kernel/spinner_scores/k{k}",
            "us_per_call": dt * 1e6,
            "derived": f"max_err={err:.1e};vmem_bytes={vmem};"
                       f"tile=({tile_v},{tile_e},{k_pad});"
                       f"pad_overhead={e_pad / n_edges:.2f};"
                       f"split_Bpe={s_bytes / n_edges:.1f};"
                       f"fused_Bpe={f_bytes / n_edges:.1f};"
                       f"hbm_drop={1 - f_bytes / s_bytes:.2f}",
            "err": err, "vmem": vmem, "e_pad": e_pad,
            "tile_config": (tile_v, tile_e, k_pad),
            "split_bytes": s_bytes, "fused_bytes": f_bytes,
            "arith_intensity_fused": mxu_flops / f_bytes,
        })

    # end-to-end: both score backends driven by the fused on-device engine
    # (interpret-mode Pallas is host-speed; the row validates the plumbing
    # and gives the XLA-backend steady-state number).  The pallas backend
    # additionally runs with the megakernel on/off, parity asserted.
    from repro.core import EngineOptions, SpinnerConfig, partition
    g_small = generators.powerlaw_ba(1000 if quick else 3000, 6, seed=1)
    for backend in ("xla",) if quick else ("xla", "pallas"):
        cfg = SpinnerConfig(k=16, seed=0, max_iters=30)
        fus = ("off",) if backend == "xla" else ("off", "on")
        res = {}
        for fu in fus:
            opts = EngineOptions(score_backend=backend, fused_update=fu)
            partition(g_small, cfg, record_history=False,
                      engine="fused", options=opts)       # compile
            t0 = time.time()
            res[fu] = partition(g_small, cfg, record_history=False,
                                engine="fused", options=opts)
            dt = time.time() - t0
            rows.append({
                "name": f"kernel/fused_engine/{backend}"
                        + (f"/fused_{fu}" if backend == "pallas" else ""),
                "us_per_call": dt * 1e6 / max(1, res[fu].iterations),
                "derived": f"iters={res[fu].iterations};total_s={dt:.3f};"
                           f"backend={backend}",
            })
        if len(res) == 2:
            assert np.array_equal(np.asarray(res["off"].labels),
                                  np.asarray(res["on"].labels)), \
                "fused megakernel diverged from split path"
    emit(rows, "bench_kernel")
    return rows


if __name__ == "__main__":
    run()
