"""Figure 8 + Table 4: application performance under Spinner vs hash.

The REAL measurement this time: every row is the device-resident
application engine (``repro.apps``) running a workload as one
``shard_map(while_loop)`` dispatch on 8 forced host devices, with

  * wall-clock per run, WARM (the program is compiled and every layout
    /plan/arg cache hot before the timed calls -- we measure dispatch,
    not tracing).  Honest-reporting note: forced host devices share one
    CPU's memory, so wall-clock does NOT see real network latency; the
    wire-byte and skew columns carry the paper's mechanism, and the
    reduction there is the transferable claim;
  * wire bytes per superstep, accumulated ON DEVICE by the exchange
    plan (the boundary-only halo / changed-values halo_delta traffic);
  * straggler skew (max/mean of per-device combined messages) -- the
    Table 4 barrier-idle proxy;
  * the static ``comm_volume`` predictor from ``metrics.summarize`` on
    every row, so the artifact correlates prediction with measurement.

Matrix: workload (PageRank / WCC / BFS) x placement (hash baseline /
Spinner) x exchange plan, plus the beyond-paper MoE expert-placement
leg (Pregel over the expert co-activation graph).  Speedup rows divide
hash by Spinner wall-clock per (workload, plan); the wire-reduction
acceptance (>= 40% on every workload) is asserted in
``tests/test_apps.py``.

The multi-device matrix runs in ONE subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
conftest-free path tests use); rows come back as JSON on stdout.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from .common import emit

_CHILD = """
import json
import time

import numpy as np

from repro.apps import APPS, run_app
from repro.core import generators, metrics
from repro.core.placement import expert_placement_case
from repro.core.spinner import SpinnerConfig, partition
from repro.launch.mesh import make_partition_mesh

QUICK = {quick}
NDEV = 8
mesh = make_partition_mesh(NDEV)

g = generators.clustered_graph(
    8, 500 if QUICK else 2000, p_in=0.02 if QUICK else 0.01,
    p_out_edges_per_v=1.0, seed=5)
res = partition(g, SpinnerConfig(k=NDEV, seed=1,
                                 max_iters=80 if QUICK else 200),
                record_history=False)
hash_l = (np.arange(g.num_vertices) * np.int64(2654435761)
          % NDEV).astype(np.int32)
placements = {{"hash": hash_l, "spinner": res.labels}}
comm_vol = {{name: metrics.summarize(g, lab, NDEV)["comm_volume"]
            for name, lab in placements.items()}}

PLANS = {{
    "pagerank": ("halo",) if QUICK else ("allgather", "halo"),
    "wcc": ("halo_delta",) if QUICK else ("halo", "halo_delta"),
    "bfs": ("halo_delta",) if QUICK else ("halo", "halo_delta"),
}}
ITERS = 5 if QUICK else 10
REPEATS = 2 if QUICK else 3


def bench_one(graph, labels, wl, plan, kvol):
    kw = dict(mesh=mesh, plan=plan, iters=ITERS)
    r = run_app(graph, labels, wl, **kw)          # warm: compile + caches
    t0 = time.perf_counter()
    for _ in range(REPEATS):
        r = run_app(graph, labels, wl, **kw)
        np.asarray(r.values)                      # block on the dispatch
    dt = (time.perf_counter() - t0) / REPEATS
    return r, dt


rows = []
for wl in ("pagerank", "wcc", "bfs"):
    for plan in PLANS[wl]:
        wall = {{}}
        for pname, labels in placements.items():
            r, dt = bench_one(g, labels, wl, plan, comm_vol[pname])
            wall[pname] = dt
            rows.append({{
                "name": f"apps/{{wl}}/{{plan}}/{{pname}}",
                "us_per_call": dt * 1e6,
                "workload": wl, "plan": plan, "placement": pname,
                "ndev": NDEV, "supersteps": r.supersteps,
                "converged": r.converged,
                "wall_s": dt,
                "wire_bytes": r.wire_bytes,
                "wire_bytes_per_step": r.wire_bytes_per_step,
                "straggler_skew": r.straggler_skew,
                "comm_volume": comm_vol[pname],
                "derived": f"wire/step={{r.wire_bytes_per_step:.0f}}B;"
                           f"skew={{r.straggler_skew:.2f}};"
                           f"steps={{r.supersteps}}",
            }})
        wp = {{p: next(x for x in rows
                      if x["name"] == f"apps/{{wl}}/{{plan}}/{{p}}")
              for p in placements}}
        red = 1 - (wp["spinner"]["wire_bytes_per_step"]
                   / max(wp["hash"]["wire_bytes_per_step"], 1e-9))
        rows.append({{
            "name": f"apps/{{wl}}/{{plan}}/speedup",
            "us_per_call": 0.0,
            "workload": wl, "plan": plan, "ndev": NDEV,
            "speedup_wall": wall["hash"] / max(wall["spinner"], 1e-12),
            "wire_reduction": red,
            "comm_volume_reduction":
                1 - comm_vol["spinner"] / max(comm_vol["hash"], 1e-9),
            "derived": f"wall_speedup="
                       f"{{wall['hash'] / max(wall['spinner'], 1e-12):.2f}}x;"
                       f"wire_reduction={{red:.1%}}",
        }})

# beyond-paper leg: Pregel over the MoE expert co-activation graph
eg, elabels, estats = expert_placement_case(
    n_experts=128 if QUICK else 512, n_tokens=1000 if QUICK else 4000,
    n_shards=NDEV, seed=0)
ehash = (np.arange(eg.num_vertices) * np.int64(2654435761)
         % NDEV).astype(np.int32)
ecomm = {{"hash": metrics.summarize(eg, ehash, NDEV)["comm_volume"],
         "spinner": metrics.summarize(eg, elabels, NDEV)["comm_volume"]}}
ewire = {{}}
for pname, labels in (("hash", ehash), ("spinner", elabels)):
    r, dt = bench_one(eg, labels, "pagerank", "halo", ecomm[pname])
    ewire[pname] = r.wire_bytes_per_step
    rows.append({{
        "name": f"apps/moe-experts/pagerank/halo/{{pname}}",
        "us_per_call": dt * 1e6,
        "workload": "pagerank", "plan": "halo", "placement": pname,
        "graph": "moe-coactivation", "ndev": NDEV,
        "wall_s": dt, "wire_bytes": r.wire_bytes,
        "wire_bytes_per_step": r.wire_bytes_per_step,
        "straggler_skew": r.straggler_skew,
        "comm_volume": ecomm[pname],
        "derived": f"wire/step={{r.wire_bytes_per_step:.0f}}B;"
                   f"skew={{r.straggler_skew:.2f}}",
    }})
rows.append({{
    "name": "apps/moe-experts/pagerank/halo/speedup",
    "us_per_call": 0.0,
    "graph": "moe-coactivation",
    "wire_reduction": 1 - ewire["spinner"] / max(ewire["hash"], 1e-9),
    "traffic_reduction": estats["traffic_reduction"],
    "derived": f"wire_reduction="
               f"{{1 - ewire['spinner'] / max(ewire['hash'], 1e-9):.1%}};"
               f"router_traffic_reduction="
               f"{{estats['traffic_reduction']:.1%}}",
}})
print("ROWS_JSON:" + json.dumps(rows, default=float))
"""


def run(quick: bool = False) -> list:
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "src"))
    code = _CHILD.format(quick=repr(bool(quick)))
    r = subprocess.run([sys.executable, "-c", code], env=env, cwd=here,
                       capture_output=True, text=True, timeout=1800)
    payload = [ln for ln in r.stdout.splitlines()
               if ln.startswith("ROWS_JSON:")]
    if not payload:
        raise RuntimeError(
            f"apps bench subprocess failed:\n{r.stdout}\n{r.stderr}")
    rows = json.loads(payload[0][len("ROWS_JSON:"):])
    emit(rows, "bench_apps")
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    rows = run(quick=ap.parse_args().quick)
    out = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_apps.json")
    with open(out, "w") as fh:
        json.dump(rows, fh, indent=1, default=float)
