"""Figure 8 + Table 4: application performance under Spinner vs hash.

Fig. 8 analogue: simulated-superstep speedup for SSSP (SP), PageRank (PR),
WCC (CC) on three graph families x partition counts matching the paper's
(LJ x 16, TU x 32, TW x 64).  Table 4 analogue: per-partition superstep
load Mean/Max/Min under random vs Spinner partitioning.  A real
distributed run (shard_map halo engine, 8 host devices) reports actual
exchanged bytes.
"""
from __future__ import annotations

import os
import subprocess
import sys

import numpy as np

from repro.core import SpinnerConfig, partition, pregel

from .common import emit, get_graph, hash_labels

WORKLOADS = (
    ("smallworld-100k", 16),   # LiveJournal-analogue
    ("clustered-64k", 32),     # Tuenti-analogue
    ("powerlaw-50k", 64),      # Twitter-analogue (hubs)
)


def run(quick: bool = False) -> list:
    rows = []
    for gname, k in WORKLOADS[: 2 if quick else 3]:
        g = get_graph(gname)
        res = partition(g, SpinnerConfig(k=k, seed=0,
                                         max_iters=60 if quick else 120),
                        record_history=False)
        h = hash_labels(g.num_vertices, k)
        for app, short in (("sssp", "SP"), ("pagerank", "PR"),
                           ("wcc", "CC")):
            kw = {"iters": 10} if app == "pagerank" else {}
            cmp = pregel.compare_partitionings(g, k, h, res.labels, app,
                                               **kw)
            rows.append({
                "name": f"apps/{gname}/k{k}/{short}",
                "us_per_call": 0.0,
                "derived": f"speedup={cmp['speedup_b_over_a']:.2f};"
                           f"msg_reduction={cmp['msg_reduction']:.1%}",
                **{kk: vv for kk, vv in cmp.items()},
                "graph": gname, "k": k,
            })
        # Table 4 analogue: per-partition load balance during PageRank
        pr_h = pregel.pagerank(g, h, k, iters=5)
        pr_s = pregel.pagerank(g, res.labels, k, iters=5)
        for tag, pr in (("random", pr_h), ("spinner", pr_s)):
            per = np.stack([s.per_partition_msgs for s in pr.stats])
            rows.append({
                "name": f"apps/{gname}/k{k}/table4_{tag}",
                "us_per_call": 0.0,
                "derived": f"mean={per.mean():.0f};max={per.max(1).mean():.0f};"
                           f"min={per.min(1).mean():.0f};"
                           f"idle_frac={(per.max(1) - per.mean(1)).mean() / per.max(1).mean():.1%}",
            })
    # real halo-exchange engine (subprocess, 8 host devices); the script is
    # the halo-volume comparison that used to live in pregel_dist._selftest
    halo_code = (
        "import numpy as np;"
        "from repro.core import generators;"
        "from repro.core.pregel_dist import pagerank_distributed;"
        "from repro.core.spinner import SpinnerConfig, partition;"
        "from repro.launch.mesh import make_partition_mesh;"
        "g = generators.watts_strogatz(4000, 12, 0.2, seed=3);"
        "mesh = make_partition_mesh();"
        "ndev = mesh.size;"
        "cfg = SpinnerConfig(k=ndev, seed=1);"
        "res = partition(g, cfg, record_history=False);"
        "hash_labels = (np.arange(g.num_vertices) * 2654435761 % ndev)"
        ".astype(np.int32);"
        "_, st_sp = pagerank_distributed(g, res.labels, mesh, iters=10);"
        "_, st_h = pagerank_distributed(g, hash_labels, mesh, iters=10);"
        "red = 1 - st_sp['halo_true_bytes_per_step']"
        " / st_h['halo_true_bytes_per_step'];"
        "print(f\"devices={ndev} halo spinner="
        "{st_sp['halo_true_bytes_per_step']}B "
        "hash={st_h['halo_true_bytes_per_step']}B reduction={red:.1%}\")"
    )
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "src"))
    r = subprocess.run([sys.executable, "-c", halo_code],
                       env=env, cwd=here, capture_output=True, text=True,
                       timeout=900)
    line = [ln for ln in r.stdout.splitlines() if "halo" in ln]
    rows.append({
        "name": "apps/distributed_halo_pagerank",
        "us_per_call": 0.0,
        "derived": line[0].strip() if line else "FAILED",
    })
    emit(rows, "bench_apps")
    return rows


if __name__ == "__main__":
    run()
