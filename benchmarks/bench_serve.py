"""Serving tier: scheduler vs naive serving, batched execution, Poisson.

Three scenarios for the multi-tenant scheduler (``repro.serve``):

* ``scheduler_vs_naive`` (headline): 8 same-bucket tenants each
  receiving BURSTS of edge-update requests.  Naive serving dispatches
  one adapt per request; the scheduler coalesces each burst into ONE
  ``apply_delta`` + one reconvergence (bit-identical results -- the
  parity tests prove it) and batches same-bucket windows.  Throughput
  ratio ~= the burst size: coalescing is a WORK reduction, so the win
  holds on any hardware.  Steady-state compile count is 0 in both modes.

* ``batched_vs_serial``: the execution layer alone -- identical
  prepared windows run through ONE vmap'd while_loop dispatch vs one
  dispatch per tenant.  This ratio is hardware-dependent: a vmapped
  iteration does ``nb`` lanes of work and runs for max(iters), so it
  needs parallel lanes (accelerator / multicore) to pay; on a 1-core
  CPU host it sits below 1 and is reported faithfully as the lane-
  parallelism baseline.

* ``poisson``: an open-loop bursty Poisson trace over a power-law
  tenant fleet at feasible load, with prefetch policies on.  Reports
  p50/p99 request latency (queueing included -- open loop), throughput,
  the coalescing factor (>1 under bursts) and batch occupancy.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import SpinnerConfig
from repro.serve import PartitionScheduler, traffic

from .common import emit

_N = 8          # same-bucket tenants (the acceptance scenario)
_V = 600
_EDGES = 12     # small deltas: stay on the O(|delta|) fast path
_BURST = 3


def _fleet(sched, graphs, cfg):
    for i, g in enumerate(graphs):
        sched.add_tenant(f"t{i}", g, cfg, partition=True)


def _scheduler_vs_naive(quick: bool) -> list:
    cfg = SpinnerConfig(k=8, max_iters=120, seed=0)
    graphs = [traffic.tenant_graph(_V + i, seed=i) for i in range(_N)]
    rounds = 2 if quick else 5
    results = {}
    for mode in ("naive", "scheduler"):
        rng = np.random.default_rng(7)    # same request stream both modes
        if mode == "naive":
            sched = PartitionScheduler(max_batch=1, batch_min=10 ** 9,
                                       policies=())
        else:   # batch_min defaults per host: stacking only where lanes pay
            sched = PartitionScheduler(max_batch=_N, policies=())
        _fleet(sched, graphs, cfg)

        def push_round():
            for i, g in enumerate(graphs):
                for _ in range(_BURST):
                    sched.submit(f"t{i}", "edge_updates",
                                 edge_updates=traffic.random_edge_updates(
                                     g.num_vertices, _EDGES, rng))
                    if mode == "naive":   # no queue depth: one per adapt
                        sched.drain()
            if mode != "naive":           # bursts queued: coalesce + batch
                sched.drain()

        push_round()                      # warm round: compiles paid here
        sched.mark()
        t0 = time.time()
        for _ in range(rounds):
            push_round()
        dt = time.time() - t0
        st = sched.stats()
        results[mode] = {
            "throughput_rps": _N * _BURST * rounds / dt,
            "seconds": dt,
            "compiles_since_mark": st["compiles_since_mark"],
            "coalescing_factor": st["coalescing_factor"],
            "batched_dispatches": st["batched_dispatches"],
            "serial_dispatches": st["serial_dispatches"],
            "fallback_adapts": sum(
                t.session.stats()["delta"]["fallback_adapts"]
                for t in sched.tenants.values()),
        }
    ratio = (results["scheduler"]["throughput_rps"]
             / results["naive"]["throughput_rps"])
    return [{
        "name": "serve_scheduler_vs_naive",
        "us_per_call": 1e6 / results["scheduler"]["throughput_rps"],
        "tenants": _N,
        "burst": _BURST,
        "rounds": rounds,
        "naive": results["naive"],
        "scheduler": results["scheduler"],
        "throughput_ratio": ratio,
        "derived": (
            f"ratio={ratio:.2f}x "
            f"naive={results['naive']['throughput_rps']:.1f}rps "
            f"sched={results['scheduler']['throughput_rps']:.1f}rps "
            f"coalesce={results['scheduler']['coalescing_factor']:.2f} "
            f"compiles={results['scheduler']['compiles_since_mark']}"),
    }]


def _batched_vs_serial(quick: bool) -> list:
    cfg = SpinnerConfig(k=8, max_iters=120, seed=0)
    graphs = [traffic.tenant_graph(_V + i, seed=i) for i in range(_N)]
    rounds = 3 if quick else 8
    results = {}
    for mode, batch_min in (("serial", 10 ** 9), ("batched", 2)):
        rng = np.random.default_rng(42)
        sched = PartitionScheduler(max_batch=_N, batch_min=batch_min,
                                   policies=())
        _fleet(sched, graphs, cfg)

        def push():
            for i, g in enumerate(graphs):
                sched.submit(f"t{i}", "edge_updates",
                             edge_updates=traffic.random_edge_updates(
                                 g.num_vertices, _EDGES, rng))

        push()
        sched.drain()
        sched.mark()
        t0 = time.time()
        for _ in range(rounds):
            push()
            sched.drain()
        dt = time.time() - t0
        st = sched.stats()
        results[mode] = {
            "throughput_rps": _N * rounds / dt,
            "seconds": dt,
            "compiles_since_mark": st["compiles_since_mark"],
            "batch_occupancy": st["batch_occupancy"],
            "batched_dispatches": st["batched_dispatches"],
            "serial_dispatches": st["serial_dispatches"],
        }
    ratio = (results["batched"]["throughput_rps"]
             / results["serial"]["throughput_rps"])
    try:
        import os
        lanes = os.cpu_count() or 1
    except Exception:
        lanes = 1
    return [{
        "name": "serve_batched_vs_serial",
        "us_per_call": 1e6 / results["batched"]["throughput_rps"],
        "tenants": _N,
        "rounds": rounds,
        "serial": results["serial"],
        "batched": results["batched"],
        "throughput_ratio": ratio,
        "host_parallel_lanes": lanes,
        "derived": (f"ratio={ratio:.2f}x (lane-bound: {lanes} host "
                    f"core{'s' if lanes != 1 else ''}) "
                    f"serial={results['serial']['throughput_rps']:.1f}rps "
                    f"batched={results['batched']['throughput_rps']:.1f}rps "
                    f"compiles={results['batched']['compiles_since_mark']}"),
    }]


def _poisson_serving(quick: bool) -> list:
    sizes = traffic.powerlaw_sizes(4 if quick else 8, v_min=256,
                                   v_max=2048, seed=1)
    names = {f"g{i}": v for i, v in enumerate(sizes)}
    cfg = SpinnerConfig(k=8, max_iters=120, seed=0)
    sched = PartitionScheduler(max_batch=8)
    for i, (name, v) in enumerate(sorted(names.items())):
        sched.add_tenant(name, traffic.tenant_graph(v, seed=i),
                         cfg, partition=True)
    # feasible open-loop load; resizes excluded (their first-compile
    # stall is characterized by the elastic suite, not queueing)
    events = traffic.poisson_trace(
        names, duration=1.5 if quick else 6.0,
        rate=0.8 if quick else 0.6, burst_mean=3.0, mix=(0.9, 0.1, 0.0),
        seed=2)
    done = traffic.replay(sched, events)
    st = sched.stats()
    return [{
        "name": "serve_poisson",
        "us_per_call": st["adapt_latency"]["p50"] * 1e6,
        "tenants": len(names),
        "events": len(events),
        "completed": done,
        "errors": st["errors"],
        "throughput_rps": st["throughput_rps"],
        "latency_p50_s": st["latency"]["p50"],
        "latency_p99_s": st["latency"]["p99"],
        "adapt_latency_p50_s": st["adapt_latency"]["p50"],
        "adapt_latency_p99_s": st["adapt_latency"]["p99"],
        "coalescing_factor": st["coalescing_factor"],
        "batch_occupancy": st["batch_occupancy"],
        "batched_dispatches": st["batched_dispatches"],
        "serial_dispatches": st["serial_dispatches"],
        "compiles": st["compiles"],
        "policies": st["policies"],
        "derived": (f"p50={st['latency']['p50'] * 1e3:.1f}ms "
                    f"p99={st['latency']['p99'] * 1e3:.1f}ms "
                    f"rps={st['throughput_rps']:.1f} "
                    f"coalesce={st['coalescing_factor']:.2f} "
                    f"occ={st['batch_occupancy']:.2f}"),
    }]


def run(quick: bool = False) -> list:
    rows = (_scheduler_vs_naive(quick) + _batched_vs_serial(quick)
            + _poisson_serving(quick))
    emit(rows, "serve")
    return rows
