"""Figure 7: adapting to resource (partition-count) changes.

Paper numbers (Tuenti, 32 -> 32+n): +1 partition adapts 74% faster than
scratch and moves < 17% of vertices (vs ~96% from scratch).

``run_fault`` (the ``cluster`` suite, ``BENCH_cluster.json``) measures
the failure side of the same elasticity story: a supervised run loses a
worker mid-stream, recovers from the newest snapshot with zero
intervention, and -- when capacity shrank -- resumes through the
elastic ``resize``.  Reported per scenario: time-to-recover, snapshots
written/restored, and post-recovery phi against the pre-fault and
uninterrupted-baseline values.
"""
from __future__ import annotations

import tempfile
import time

from repro.core import SpinnerConfig, metrics, partition, resize

from .common import emit, get_graph, timed


def run(quick: bool = False) -> list:
    g = get_graph("smallworld-100k")
    k0 = 32
    cfg0 = SpinnerConfig(k=k0, seed=0, max_iters=80 if quick else 150)
    # fused engine: elastic restarts are a single device dispatch
    base, _ = timed(partition, g, cfg0, record_history=False,
                    engine="fused")
    rows = []
    for n_new in (1, 4) if quick else (1, 2, 4, 8, 16, 32):
        k = k0 + n_new
        cfg = SpinnerConfig(k=k, seed=1, max_iters=80 if quick else 150)
        scratch, t_scr = timed(partition, g, cfg, record_history=False,
                               engine="fused")
        (adapted, relabeled), t_ad = timed(resize, g, base.labels, cfg, k0,
                                           record_history=False,
                                           engine="fused")
        time_saving = 1 - t_ad / t_scr
        msg_saving = 1 - adapted.total_messages / max(
            1.0, scratch.total_messages)
        diff_ad = metrics.partitioning_difference(base.labels,
                                                  adapted.labels)
        diff_scr = metrics.partitioning_difference(base.labels,
                                                   scratch.labels)
        rows.append({
            "name": f"elastic/add_{n_new}_partitions",
            "us_per_call": t_ad * 1e6,
            "derived": f"time_saving={time_saving:.1%};"
                       f"msg_saving={msg_saving:.1%};"
                       f"moved_adaptive={diff_ad:.1%};"
                       f"moved_scratch={diff_scr:.1%};"
                       f"rho={metrics.rho(g, adapted.labels, k):.3f};"
                       f"phi={metrics.phi(g, adapted.labels):.3f}",
            "n_new": n_new, "time_saving": time_saving,
            "msg_saving": msg_saving, "moved_adaptive": diff_ad,
            "moved_scratch": diff_scr,
            "rho": metrics.rho(g, adapted.labels, k),
            "phi": metrics.phi(g, adapted.labels),
        })
    emit(rows, "bench_elastic")
    return rows


def run_fault(quick: bool = False) -> list:
    """Fault-injection mode: supervised kill -> snapshot recovery."""
    from repro.cluster import (ClusterSupervisorConfig, PartitionSupervisor,
                               kill_worker_at)
    from repro.core.session import PartitionSession

    g = get_graph("smallworld-100k")
    max_iters = 60 if quick else 120
    scenarios = [
        # (name, k0, ndev_before, ndev_after)  -- None = same capacity
        ("same_capacity", 32, 1, None),
        ("shrink_8_to_4", 32, 8, 4),
    ]
    work = [("partition", {})] + [("adapt", {})] * 2
    rows = []
    for name, k0, nd0, nd1 in scenarios:
        cfg = SpinnerConfig(k=k0, seed=0, max_iters=max_iters)

        def factory(ndev, cfg=cfg):
            return g, cfg, None     # 1 physical device: ndev is logical

        snap = tempfile.mkdtemp(prefix=f"bench_cluster_{name}_")
        sup = PartitionSupervisor(
            ClusterSupervisorConfig(snapshot_dir=snap), factory)
        t0 = time.perf_counter()
        session, results = sup.run(
            work, ndev=nd0,
            faults=[kill_worker_at(2, surviving_ndev=nd1)])
        wall = time.perf_counter() - t0
        st = sup.stats()
        phi_pre = metrics.phi(g, results[0].labels)
        phi_post = metrics.phi(g, session.labels)
        k_final = st["k"]

        # uninterrupted baseline at the post-recovery k
        base = PartitionSession(
            g, SpinnerConfig(k=k_final, seed=0, max_iters=max_iters))
        phi_base = metrics.phi(
            g, base.partition(record_history=False).labels)
        base.close(), session.close()

        recover_s = sum(st["recover_seconds"])
        rows.append({
            "name": f"cluster/{name}",
            "us_per_call": recover_s * 1e6,    # time-to-recover
            "derived": f"recover_s={recover_s:.3f};"
                       f"snapshots_written={st['snapshots_written']};"
                       f"snapshots_restored={st['snapshots_restored']};"
                       f"phi_pre_fault={phi_pre:.3f};"
                       f"phi_post_recovery={phi_post:.3f};"
                       f"phi_uninterrupted={phi_base:.3f};"
                       f"k_final={k_final};resized={st['resized_on_restore']}",
            "time_to_recover_s": recover_s,
            "wall_s": wall,
            "restarts": st["restarts"],
            "snapshots_written": st["snapshots_written"],
            "snapshots_restored": st["snapshots_restored"],
            "phi_pre_fault": phi_pre,
            "phi_post_recovery": phi_post,
            "phi_uninterrupted": phi_base,
            "phi_vs_baseline": phi_post / max(phi_base, 1e-12),
            "k_final": k_final,
            "ndev_before": nd0,
            "ndev_after": nd1 if nd1 is not None else nd0,
            "resized": st["resized_on_restore"],
        })
        assert rows[-1]["phi_vs_baseline"] >= 0.98, rows[-1]
    emit(rows, "bench_cluster")
    return rows


if __name__ == "__main__":
    run()
