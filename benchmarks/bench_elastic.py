"""Figure 7: adapting to resource (partition-count) changes.

Paper numbers (Tuenti, 32 -> 32+n): +1 partition adapts 74% faster than
scratch and moves < 17% of vertices (vs ~96% from scratch).
"""
from __future__ import annotations

from repro.core import SpinnerConfig, metrics, partition, resize

from .common import emit, get_graph, timed


def run(quick: bool = False) -> list:
    g = get_graph("smallworld-100k")
    k0 = 32
    cfg0 = SpinnerConfig(k=k0, seed=0, max_iters=80 if quick else 150)
    # fused engine: elastic restarts are a single device dispatch
    base, _ = timed(partition, g, cfg0, record_history=False,
                    engine="fused")
    rows = []
    for n_new in (1, 4) if quick else (1, 2, 4, 8, 16, 32):
        k = k0 + n_new
        cfg = SpinnerConfig(k=k, seed=1, max_iters=80 if quick else 150)
        scratch, t_scr = timed(partition, g, cfg, record_history=False,
                               engine="fused")
        (adapted, relabeled), t_ad = timed(resize, g, base.labels, cfg, k0,
                                           record_history=False,
                                           engine="fused")
        time_saving = 1 - t_ad / t_scr
        msg_saving = 1 - adapted.total_messages / max(
            1.0, scratch.total_messages)
        diff_ad = metrics.partitioning_difference(base.labels,
                                                  adapted.labels)
        diff_scr = metrics.partitioning_difference(base.labels,
                                                   scratch.labels)
        rows.append({
            "name": f"elastic/add_{n_new}_partitions",
            "us_per_call": t_ad * 1e6,
            "derived": f"time_saving={time_saving:.1%};"
                       f"msg_saving={msg_saving:.1%};"
                       f"moved_adaptive={diff_ad:.1%};"
                       f"moved_scratch={diff_scr:.1%};"
                       f"rho={metrics.rho(g, adapted.labels, k):.3f};"
                       f"phi={metrics.phi(g, adapted.labels):.3f}",
            "n_new": n_new, "time_saving": time_saving,
            "msg_saving": msg_saving, "moved_adaptive": diff_ad,
            "moved_scratch": diff_scr,
            "rho": metrics.rho(g, adapted.labels, k),
            "phi": metrics.phi(g, adapted.labels),
        })
    emit(rows, "bench_elastic")
    return rows


if __name__ == "__main__":
    run()
