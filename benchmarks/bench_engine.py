"""Host vs fused vs chunked vs sharded engine: dispatch overhead.

The fused runner executes the whole run as one ``lax.while_loop`` device
call; the host loop pays a dispatch + sync round-trip per iteration.  This
suite isolates that overhead: each runner is compiled once, then timed on a
steady-state run with the same seed (so all engines execute the identical
label trajectory and iteration count), and the per-iteration gap between
host and fused is reported as dispatch overhead.

The sharded section measures the same quantity for the mesh engine: the
single ``shard_map(while_loop)`` dispatch of ``engine="sharded"`` against
``run_sharded_hostloop``, the pre-PR-2 driving mode that dispatches the
identical sharded step once per iteration with a host sync on
``state.halted``.  Both walk the same trajectory bit for bit, so the gap
is pure dispatch/sync cost -- the overhead this PR removes from the
distributed path.  (In-process this runs on a 1-device mesh; see
EXPERIMENTS.md for the multi-device workers sweep.)
"""
from __future__ import annotations

import time

import jax

from repro.core import SpinnerConfig, engine, partition, prepare_init
from repro.core.distributed import run_sharded_hostloop
from repro.launch.mesh import make_partition_mesh

from .common import emit, get_graph


def _time_engine(graph, cfg, eng, chunk_size=None):
    """(seconds_warm, iterations): second call timed, first pays compile."""
    kw = {"record_history": False, "engine": eng}
    if chunk_size is not None:
        kw["chunk_size"] = chunk_size
    res = partition(graph, cfg, **kw)        # warm-up/compile
    t0 = time.time()
    res = partition(graph, cfg, **kw)
    return time.time() - t0, res.iterations


def run(quick: bool = False) -> list:
    g = get_graph("powerlaw-50k" if quick else "smallworld-100k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=40 if quick else 100)
    rows = []

    t_host, iters = _time_engine(g, cfg, "host")
    t_fused, it_f = _time_engine(g, cfg, "fused")
    # both engines run f32 halting, so counts should agree; report rather
    # than assert so a divergence can't abort the whole benchmark run
    parity = "ok" if it_f == iters else f"DIVERGED({iters}vs{it_f})"
    per_host = t_host / max(1, iters)
    per_fused = t_fused / max(1, it_f)
    rows.append({
        "name": "engine/host",
        "us_per_call": per_host * 1e6,
        "derived": f"iters={iters};total_s={t_host:.3f}",
        "iterations": iters, "total_s": t_host,
    })
    rows.append({
        "name": "engine/fused",
        "us_per_call": per_fused * 1e6,
        "derived": f"iters={it_f};total_s={t_fused:.3f};"
                   f"speedup={per_host / max(per_fused, 1e-12):.2f}x;"
                   f"parity={parity}",
        "iterations": it_f, "total_s": t_fused,
    })
    rows.append({
        "name": "engine/dispatch_overhead",
        "us_per_call": (per_host - per_fused) * 1e6,
        "derived": f"host_per_iter_us={per_host * 1e6:.1f};"
                   f"fused_per_iter_us={per_fused * 1e6:.1f}",
    })

    for chunk in (8, 32):
        t_chunk, it_c = _time_engine(g, cfg, "chunked", chunk_size=chunk)
        per_chunk = t_chunk / max(1, it_c)
        dispatches = -(-it_c // chunk)
        rows.append({
            "name": f"engine/chunked_cs{chunk}",
            "us_per_call": per_chunk * 1e6,
            "derived": f"iters={it_c};dispatches={dispatches};"
                       f"total_s={t_chunk:.3f};"
                       f"speedup_vs_host={per_host / max(per_chunk, 1e-12):.2f}x",
            "iterations": it_c, "dispatches": dispatches,
        })

    # sharded engine: one shard_map(while_loop) dispatch vs per-iteration
    # host driving of the same sharded step (identical trajectory)
    mesh = make_partition_mesh()
    kw = {"record_history": False, "engine": "sharded", "mesh": mesh}
    partition(g, cfg, **kw)                  # warm-up/compile
    t0 = time.time()
    res_sh = partition(g, cfg, **kw)
    t_sharded = time.time() - t0
    it_s = res_sh.iterations
    per_sharded = t_sharded / max(1, it_s)

    state = run_sharded_hostloop(g, cfg, mesh)   # warm-up/compile
    t0 = time.time()
    state = run_sharded_hostloop(g, cfg, mesh)
    t_hloop = time.time() - t0
    it_h = int(state.iteration)
    per_hloop = t_hloop / max(1, it_h)
    parity_sh = "ok" if it_h == it_s else f"DIVERGED({it_s}vs{it_h})"
    rows.append({
        "name": "engine/sharded_fused",
        "us_per_call": per_sharded * 1e6,
        "derived": f"iters={it_s};total_s={t_sharded:.3f};"
                   f"mesh={mesh.size}dev",
        "iterations": it_s, "total_s": t_sharded,
    })
    rows.append({
        "name": "engine/sharded_hostloop",
        "us_per_call": per_hloop * 1e6,
        "derived": f"iters={it_h};total_s={t_hloop:.3f};"
                   f"speedup_fused={per_hloop / max(per_sharded, 1e-12):.2f}x;"
                   f"parity={parity_sh}",
        "iterations": it_h, "total_s": t_hloop,
    })
    rows.append({
        "name": "engine/sharded_dispatch_overhead",
        "us_per_call": (per_hloop - per_sharded) * 1e6,
        "derived": f"hostloop_per_iter_us={per_hloop * 1e6:.1f};"
                   f"sharded_per_iter_us={per_sharded * 1e6:.1f}",
    })

    # compile cost of the single-dispatch path (first call - steady state)
    labels, loads, key = prepare_init(g, cfg)
    runner = engine.make_fused_runner(g, cfg)
    state0 = engine.init_state(labels, loads, key)
    t0 = time.time()
    jax.block_until_ready(runner(state0))
    t_cold = time.time() - t0
    rows.append({
        "name": "engine/fused_compile",
        "us_per_call": (t_cold - t_fused) * 1e6,
        "derived": f"cold_s={t_cold:.3f};steady_s={t_fused:.3f}",
    })

    emit(rows, "bench_engine")
    return rows


if __name__ == "__main__":
    run()
