"""Host vs fused vs chunked engine: per-iteration dispatch overhead.

The fused runner executes the whole run as one ``lax.while_loop`` device
call; the host loop pays a dispatch + sync round-trip per iteration.  This
suite isolates that overhead: each runner is compiled once, then timed on a
steady-state run with the same seed (so all engines execute the identical
label trajectory and iteration count), and the per-iteration gap between
host and fused is reported as dispatch overhead.
"""
from __future__ import annotations

import time

import jax

from repro.core import SpinnerConfig, engine, partition, prepare_init

from .common import emit, get_graph


def _time_engine(graph, cfg, eng, chunk_size=None):
    """(seconds_warm, iterations): second call timed, first pays compile."""
    kw = {"record_history": False, "engine": eng}
    if chunk_size is not None:
        kw["chunk_size"] = chunk_size
    res = partition(graph, cfg, **kw)        # warm-up/compile
    t0 = time.time()
    res = partition(graph, cfg, **kw)
    return time.time() - t0, res.iterations


def run(quick: bool = False) -> list:
    g = get_graph("powerlaw-50k" if quick else "smallworld-100k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=40 if quick else 100)
    rows = []

    t_host, iters = _time_engine(g, cfg, "host")
    t_fused, it_f = _time_engine(g, cfg, "fused")
    # both engines run f32 halting, so counts should agree; report rather
    # than assert so a divergence can't abort the whole benchmark run
    parity = "ok" if it_f == iters else f"DIVERGED({iters}vs{it_f})"
    per_host = t_host / max(1, iters)
    per_fused = t_fused / max(1, it_f)
    rows.append({
        "name": "engine/host",
        "us_per_call": per_host * 1e6,
        "derived": f"iters={iters};total_s={t_host:.3f}",
        "iterations": iters, "total_s": t_host,
    })
    rows.append({
        "name": "engine/fused",
        "us_per_call": per_fused * 1e6,
        "derived": f"iters={it_f};total_s={t_fused:.3f};"
                   f"speedup={per_host / max(per_fused, 1e-12):.2f}x;"
                   f"parity={parity}",
        "iterations": it_f, "total_s": t_fused,
    })
    rows.append({
        "name": "engine/dispatch_overhead",
        "us_per_call": (per_host - per_fused) * 1e6,
        "derived": f"host_per_iter_us={per_host * 1e6:.1f};"
                   f"fused_per_iter_us={per_fused * 1e6:.1f}",
    })

    for chunk in (8, 32):
        t_chunk, it_c = _time_engine(g, cfg, "chunked", chunk_size=chunk)
        per_chunk = t_chunk / max(1, it_c)
        dispatches = -(-it_c // chunk)
        rows.append({
            "name": f"engine/chunked_cs{chunk}",
            "us_per_call": per_chunk * 1e6,
            "derived": f"iters={it_c};dispatches={dispatches};"
                       f"total_s={t_chunk:.3f};"
                       f"speedup_vs_host={per_host / max(per_chunk, 1e-12):.2f}x",
            "iterations": it_c, "dispatches": dispatches,
        })

    # compile cost of the single-dispatch path (first call - steady state)
    labels, loads, key = prepare_init(g, cfg)
    runner = engine.make_fused_runner(g, cfg)
    state0 = engine.init_state(labels, loads, key)
    t0 = time.time()
    jax.block_until_ready(runner(state0))
    t_cold = time.time() - t0
    rows.append({
        "name": "engine/fused_compile",
        "us_per_call": (t_cold - t_fused) * 1e6,
        "derived": f"cold_s={t_cold:.3f};steady_s={t_fused:.3f}",
    })

    emit(rows, "bench_engine")
    return rows


if __name__ == "__main__":
    run()
