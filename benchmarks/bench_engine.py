"""Host vs fused vs chunked vs sharded engine: dispatch overhead.

The fused runner executes the whole run as one ``lax.while_loop`` device
call; the host loop pays a dispatch + sync round-trip per iteration.  This
suite isolates that overhead: each runner is compiled once, then timed on a
steady-state run with the same seed (so all engines execute the identical
label trajectory and iteration count), and the per-iteration gap between
host and fused is reported as dispatch overhead.

The sharded section measures the same quantity for the mesh engine: the
single ``shard_map(while_loop)`` dispatch of ``engine="sharded"`` against
``run_sharded_hostloop``, the pre-PR-2 driving mode that dispatches the
identical sharded step once per iteration with a host sync on
``state.halted``.  Both walk the same trajectory bit for bit, so the gap
is pure dispatch/sync cost -- the overhead this PR removes from the
distributed path.  (In-process this runs on a 1-device mesh; see
EXPERIMENTS.md for the multi-device workers sweep.)

The exchange-mode matrix (subprocess, 8 forced host devices, clustered
graph) compares the three label-exchange plans -- allgather / halo /
delta, identical trajectories by construction -- on per-iteration bytes
on the wire next to wall-clock: the Section 3.3 / Figure 7 claim that
converging LPA needs ever less communication, measured on device.  The
``sharded_pallas`` row times the per-shard tiled MXU kernel inside
``shard_map`` (interpret mode off-TPU, so it is a correctness/coverage
row there, not a speed claim).

The overlap matrix (subprocess, 8 forced host devices) times the
interior/frontier overlap schedule (``EngineOptions.overlap``) against
the sequential exchange->score step on the same mesh and plan --
bit-identical trajectories, so the gap is pure schedule -- and reports
the layout's frontier fraction (the share of scoring that must wait for
the wire).  The ``staged_adapt`` row measures the session's
double-buffered uploads: ``stage()`` issues the next snapshot's
transfers ahead of time, so the following ``adapt()`` dispatches from a
device-resident bind (compare against ``session_cold_adapt`` /
``session_warm_adapt``).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax

import numpy as np

from repro.core import EngineOptions, SpinnerConfig, adapt, engine, \
    generators, open_session, partition, prepare_init
from repro.core.graph import add_edges
from repro.core.distributed import run_sharded_hostloop
from repro.launch.mesh import make_partition_mesh

from .common import emit, get_graph

EXCHANGE_MATRIX_CODE = """
import time
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, {n_per}, 0.02, 0.5, seed=5)
cfg = SpinnerConfig(k=8, seed=1, max_iters={max_iters})
mesh = make_partition_mesh()
for mode in ("allgather", "halo", "delta"):
    kw = dict(record_history=False, engine="sharded", mesh=mesh,
              options=EngineOptions(label_exchange=mode))
    partition(g, cfg, **kw)                       # warm-up/compile
    t0 = time.time()
    res = partition(g, cfg, **kw)
    dt = time.time() - t0
    bpi = res.exchanged_bytes / max(1, res.iterations)
    print(f"MODE {{mode}} ndev={{mesh.size}} iters={{res.iterations}} "
          f"total_s={{dt:.3f}} bytes_per_iter={{bpi:.0f}}")
"""


def _exchange_matrix_rows(quick: bool) -> list:
    """allgather/halo/delta wire bytes + wall-clock on an 8-device mesh."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "src"))
    code = EXCHANGE_MATRIX_CODE.format(n_per=250 if quick else 500,
                                       max_iters=60 if quick else 120)
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           env=env, cwd=here, capture_output=True,
                           text=True, timeout=900)
        stdout, err = r.stdout, ("" if r.returncode == 0 else
                                 f"rc={r.returncode}: {r.stderr.strip()}")
    except subprocess.TimeoutExpired as e:
        stdout, err = "", f"timeout after {e.timeout}s"
    rows = []
    parsed = {}
    if not err:
        for line in stdout.splitlines():
            if not line.startswith("MODE "):
                continue
            fields = dict(f.split("=") for f in line.split()[2:])
            parsed[line.split()[1]] = fields
    ag_bytes = float(parsed.get("allgather", {}).get("bytes_per_iter", 0))
    for mode, f in parsed.items():
        bpi = float(f["bytes_per_iter"])
        red = 1 - bpi / ag_bytes if ag_bytes and mode != "allgather" else 0.0
        iters = int(f["iters"])
        rows.append({
            "name": f"engine/exchange_{mode}",
            "us_per_call": float(f["total_s"]) / max(1, iters) * 1e6,
            "derived": f"ndev={f['ndev']};iters={iters};"
                       f"bytes_per_iter={bpi:.0f}"
                       + (f";vs_allgather=-{red:.1%}" if mode != "allgather"
                          else ""),
            "bytes_per_iter": bpi,
        })
    if not rows:
        rows.append({"name": "engine/exchange_matrix", "us_per_call": 0.0,
                     "derived": "FAILED: " + (err or "no MODE lines")[-200:]})
    return rows


OVERLAP_MATRIX_CODE = """
import time
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, metrics, \\
    partition
from repro.core.distributed import shard_layout
from repro.core.engine import padded_view
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, {n_per}, 0.02, 0.5, seed=5)
cfg = SpinnerConfig(k=8, seed=1, max_iters={max_iters})
mesh = make_partition_mesh()
labels = {{}}
for ov in ("off", "on"):
    opts = EngineOptions(label_exchange="halo", overlap=ov)
    kw = dict(record_history=False, engine="sharded", mesh=mesh,
              options=opts)
    partition(g, cfg, **kw)                       # warm-up/compile
    t0 = time.time()
    res = partition(g, cfg, **kw)
    dt = time.time() - t0
    labels[ov] = res.labels
    padded, _ = padded_view(g, opts)
    sg = shard_layout(padded, mesh.size, pad=True)
    bpi = res.exchanged_bytes / max(1, res.iterations)
    print(f"OVERLAP {{ov}} ndev={{mesh.size}} iters={{res.iterations}} "
          f"total_s={{dt:.3f}} bytes_per_iter={{bpi:.0f}} "
          f"frontier_fraction={{metrics.frontier_fraction(sg):.3f}}")
assert (labels["off"] == labels["on"]).all()      # pure schedule change
"""


def _overlap_matrix_rows(quick: bool) -> list:
    """Overlap-on vs overlap-off wall-clock on an 8-device mesh (halo
    plan; identical trajectories, asserted in the subprocess)."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "src"))
    code = OVERLAP_MATRIX_CODE.format(n_per=250 if quick else 500,
                                      max_iters=60 if quick else 120)
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           env=env, cwd=here, capture_output=True,
                           text=True, timeout=900)
        err = ("" if r.returncode == 0 else
               f"rc={r.returncode}: {r.stderr.strip()}")
        stdout = r.stdout
    except subprocess.TimeoutExpired as e:
        stdout, err = "", f"timeout after {e.timeout}s"
    rows = []
    parsed = {}
    if not err:
        for line in stdout.splitlines():
            if line.startswith("OVERLAP "):
                parsed[line.split()[1]] = dict(
                    f.split("=") for f in line.split()[2:])
    t_off = float(parsed.get("off", {}).get("total_s", 0))
    for ov, f in parsed.items():
        dt = float(f["total_s"])
        iters = int(f["iters"])
        extra = (f";vs_overlap_off={t_off / max(dt, 1e-12):.2f}x"
                 if ov == "on" and t_off else "")
        rows.append({
            "name": f"engine/overlap_{ov}",
            "us_per_call": dt / max(1, iters) * 1e6,
            "derived": f"ndev={f['ndev']};iters={iters};"
                       f"total_s={dt:.3f};plan=halo;"
                       f"frontier_fraction={f['frontier_fraction']};"
                       f"bytes_per_iter={f['bytes_per_iter']}" + extra,
        })
    if not rows:
        rows.append({"name": "engine/overlap_matrix", "us_per_call": 0.0,
                     "derived": "FAILED: "
                                + (err or "no OVERLAP lines")[-200:]})
    return rows


FUSED_MATRIX_CODE = """
import time
import numpy as np
from repro.core import EngineOptions, SpinnerConfig, generators, partition
from repro.launch.mesh import make_partition_mesh

g = generators.clustered_graph(8, {n_per}, 0.02, 0.5, seed=5)
cfg = SpinnerConfig(k=8, seed=1, max_iters={max_iters})
mesh = make_partition_mesh()
labels = {{}}
for fu in ("off", "on"):
    opts = EngineOptions(score_backend="pallas", label_exchange="halo",
                         fused_update=fu)
    kw = dict(record_history=False, engine="sharded", mesh=mesh,
              options=opts)
    partition(g, cfg, **kw)                       # warm-up/compile
    t0 = time.time()
    res = partition(g, cfg, **kw)
    dt = time.time() - t0
    labels[fu] = res.labels
    print(f"FUSED {{fu}} ndev={{mesh.size}} iters={{res.iterations}} "
          f"total_s={{dt:.3f}}")
assert (labels["off"] == labels["on"]).all()      # bit-exact megakernel
"""


def _fused_matrix_rows(quick: bool) -> list:
    """Fused megakernel on vs off on an 8-device mesh (pallas backend,
    halo plan; identical trajectories, asserted in the subprocess).
    Interpret-mode Pallas runs the kernel op-by-op on host, so the
    wall-clock here tracks dispatch count, not the TPU win the roofline
    mode models."""
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(here, "src"))
    code = FUSED_MATRIX_CODE.format(n_per=100 if quick else 200,
                                    max_iters=20 if quick else 40)
    try:
        r = subprocess.run([sys.executable, "-c", code],
                           env=env, cwd=here, capture_output=True,
                           text=True, timeout=900)
        err = ("" if r.returncode == 0 else
               f"rc={r.returncode}: {r.stderr.strip()}")
        stdout = r.stdout
    except subprocess.TimeoutExpired as e:
        stdout, err = "", f"timeout after {e.timeout}s"
    rows = []
    parsed = {}
    if not err:
        for line in stdout.splitlines():
            if line.startswith("FUSED "):
                parsed[line.split()[1]] = dict(
                    f.split("=") for f in line.split()[2:])
    for fu, f in parsed.items():
        dt = float(f["total_s"])
        iters = int(f["iters"])
        rows.append({
            "name": f"engine/fused_update_{fu}",
            "us_per_call": dt / max(1, iters) * 1e6,
            "derived": f"ndev={f['ndev']};iters={iters};"
                       f"total_s={dt:.3f};plan=halo;backend=pallas",
        })
    if not rows:
        rows.append({"name": "engine/fused_matrix", "us_per_call": 0.0,
                     "derived": "FAILED: "
                                + (err or "no FUSED lines")[-200:]})
    return rows


def _time_engine(graph, cfg, eng, chunk_size=None):
    """(seconds_warm, iterations): second call timed, first pays compile."""
    kw = {"record_history": False, "engine": eng}
    if chunk_size is not None:
        kw["chunk_size"] = chunk_size
    res = partition(graph, cfg, **kw)        # warm-up/compile
    t0 = time.time()
    res = partition(graph, cfg, **kw)
    return time.time() - t0, res.iterations


def run(quick: bool = False) -> list:
    g = get_graph("powerlaw-50k" if quick else "smallworld-100k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=40 if quick else 100)
    rows = []

    t_host, iters = _time_engine(g, cfg, "host")
    t_fused, it_f = _time_engine(g, cfg, "fused")
    # both engines run f32 halting, so counts should agree; report rather
    # than assert so a divergence can't abort the whole benchmark run
    parity = "ok" if it_f == iters else f"DIVERGED({iters}vs{it_f})"
    per_host = t_host / max(1, iters)
    per_fused = t_fused / max(1, it_f)
    rows.append({
        "name": "engine/host",
        "us_per_call": per_host * 1e6,
        "derived": f"iters={iters};total_s={t_host:.3f}",
        "iterations": iters, "total_s": t_host,
    })
    rows.append({
        "name": "engine/fused",
        "us_per_call": per_fused * 1e6,
        "derived": f"iters={it_f};total_s={t_fused:.3f};"
                   f"speedup={per_host / max(per_fused, 1e-12):.2f}x;"
                   f"parity={parity}",
        "iterations": it_f, "total_s": t_fused,
    })
    rows.append({
        "name": "engine/dispatch_overhead",
        "us_per_call": (per_host - per_fused) * 1e6,
        "derived": f"host_per_iter_us={per_host * 1e6:.1f};"
                   f"fused_per_iter_us={per_fused * 1e6:.1f}",
    })

    for chunk in (8, 32):
        t_chunk, it_c = _time_engine(g, cfg, "chunked", chunk_size=chunk)
        per_chunk = t_chunk / max(1, it_c)
        dispatches = -(-it_c // chunk)
        rows.append({
            "name": f"engine/chunked_cs{chunk}",
            "us_per_call": per_chunk * 1e6,
            "derived": f"iters={it_c};dispatches={dispatches};"
                       f"total_s={t_chunk:.3f};"
                       f"speedup_vs_host={per_host / max(per_chunk, 1e-12):.2f}x",
            "iterations": it_c, "dispatches": dispatches,
        })

    # sharded engine: one shard_map(while_loop) dispatch vs per-iteration
    # host driving of the same sharded step (identical trajectory)
    mesh = make_partition_mesh()
    kw = {"record_history": False, "engine": "sharded", "mesh": mesh}
    partition(g, cfg, **kw)                  # warm-up/compile
    t0 = time.time()
    res_sh = partition(g, cfg, **kw)
    t_sharded = time.time() - t0
    it_s = res_sh.iterations
    per_sharded = t_sharded / max(1, it_s)

    state = run_sharded_hostloop(g, cfg, mesh)   # warm-up/compile
    t0 = time.time()
    state = run_sharded_hostloop(g, cfg, mesh)
    t_hloop = time.time() - t0
    it_h = int(state.iteration)
    per_hloop = t_hloop / max(1, it_h)
    parity_sh = "ok" if it_h == it_s else f"DIVERGED({it_s}vs{it_h})"
    rows.append({
        "name": "engine/sharded_fused",
        "us_per_call": per_sharded * 1e6,
        "derived": f"iters={it_s};total_s={t_sharded:.3f};"
                   f"mesh={mesh.size}dev",
        "iterations": it_s, "total_s": t_sharded,
    })
    rows.append({
        "name": "engine/sharded_hostloop",
        "us_per_call": per_hloop * 1e6,
        "derived": f"iters={it_h};total_s={t_hloop:.3f};"
                   f"speedup_fused={per_hloop / max(per_sharded, 1e-12):.2f}x;"
                   f"parity={parity_sh}",
        "iterations": it_h, "total_s": t_hloop,
    })
    rows.append({
        "name": "engine/sharded_dispatch_overhead",
        "us_per_call": (per_hloop - per_sharded) * 1e6,
        "derived": f"hostloop_per_iter_us={per_hloop * 1e6:.1f};"
                   f"sharded_per_iter_us={per_sharded * 1e6:.1f}",
    })

    # exchange-mode matrix: bytes on the wire per iteration per plan,
    # measured on a real 8-device mesh (subprocess, forced host devices)
    rows.extend(_exchange_matrix_rows(quick))

    # overlap schedule: interior scoring concurrent with the halo
    # exchange vs the sequential step, same mesh and trajectory
    rows.extend(_overlap_matrix_rows(quick))
    rows.extend(_fused_matrix_rows(quick))

    # Figure 7 traffic decay: the delta plan ships one (index, label) pair
    # per migration to each peer, so the per-iteration wire volume is the
    # migration curve -- run a clustered graph to convergence and read the
    # decay from the chunked history
    g_cl = generators.clustered_graph(8, 250 if quick else 500, 0.02, 0.5,
                                      seed=5)
    hist = partition(g_cl, SpinnerConfig(k=8, seed=1,
                                         max_iters=60 if quick else 120),
                     engine="chunked").history
    if hist:
        ndev_hypo = 8
        decay = [h["migrations"] * 8 * (ndev_hypo - 1) for h in hist]
        picks = {i: decay[i] for i in (0, len(decay) // 4, len(decay) // 2,
                                       len(decay) - 1)}
        allgather_bpi = (ndev_hypo - 1) * g_cl.num_vertices * 4
        rows.append({
            "name": "engine/delta_traffic_decay",
            "us_per_call": 0.0,
            "derived": ";".join(f"iter{i + 1}={b}B"
                                for i, b in sorted(picks.items()))
                       + f";allgather={allgather_bpi}B/iter(ndev=8)",
        })

    # sharded Pallas score backend inside shard_map (interpret off-TPU):
    # a small fixed-iteration run -- interpret mode emulates the MXU
    # kernel op-by-op, so this row tracks coverage/cost, not TPU speed
    g_pal = generators.watts_strogatz(1000 if quick else 2000, 10, 0.2,
                                      seed=9)
    cfg_pal = SpinnerConfig(k=16, seed=0, max_iters=4 if quick else 6)
    mesh1 = make_partition_mesh(1)
    kw = {"record_history": False, "engine": "sharded", "mesh": mesh1,
          "options": EngineOptions(score_backend="pallas")}
    partition(g_pal, cfg_pal, **kw)              # warm-up/compile
    t0 = time.time()
    res_p = partition(g_pal, cfg_pal, **kw)
    t_pal = time.time() - t0
    kw["options"] = EngineOptions(score_backend="xla")
    partition(g_pal, cfg_pal, **kw)              # warm-up/compile
    t0 = time.time()
    res_x = partition(g_pal, cfg_pal, **kw)
    t_xla = time.time() - t0
    parity_p = ("ok" if (res_p.labels == res_x.labels).all()
                else "DIVERGED")
    rows.append({
        "name": "engine/sharded_pallas",
        "us_per_call": t_pal / max(1, res_p.iterations) * 1e6,
        "derived": f"iters={res_p.iterations};total_s={t_pal:.3f};"
                   f"interpret={jax.default_backend() != 'tpu'};"
                   f"xla_total_s={t_xla:.3f};parity={parity_p}",
        "iterations": res_p.iterations, "total_s": t_pal,
    })

    # session amortization (PR 4): a long-lived PartitionSession compiles
    # its fused runner against the graph's (V, E) shape bucket, so a warm
    # adapt() on a grown same-bucket graph pays upload + dispatch only.
    # Cold = one-shot adapt with fresh cfg statics (nothing pre-compiled:
    # full trace + XLA compile on the critical path); warm = the live
    # session (zero new compiles, asserted).
    g_s = generators.watts_strogatz(3000 if quick else 10_000, 10, 0.2,
                                    seed=13)
    v_s = g_s.num_vertices
    rng = np.random.default_rng(5)
    sess_cfg = SpinnerConfig(k=16, seed=0, max_iters=41)
    sess = open_session(g_s, sess_cfg, EngineOptions(engine="fused"))
    res0 = sess.partition(record_history=False)
    g_grown = add_edges(g_s, rng.integers(0, v_s, 200),
                        rng.integers(0, v_s, 200), num_vertices=v_s + 10)
    cold_cfg = SpinnerConfig(k=16, seed=0, max_iters=43)   # fresh statics
    t0 = time.time()
    res_cold = adapt(g_grown, res0.labels, cold_cfg, record_history=False)
    t_cold_adapt = time.time() - t0
    compiles_before = sess.compiles
    t0 = time.time()
    res_warm = sess.adapt(g_grown, record_history=False)
    t_warm_adapt = time.time() - t0
    warm_compiles = sess.compiles - compiles_before
    parity_s = ("ok" if (res_cold.labels == res_warm.labels).all()
                else "DIVERGED")
    rows.append({
        "name": "engine/session_cold_adapt",
        "us_per_call": t_cold_adapt * 1e6,
        "derived": f"iters={res_cold.iterations};"
                   f"total_s={t_cold_adapt:.3f};compile_on_path=1",
    })
    rows.append({
        "name": "engine/session_warm_adapt",
        "us_per_call": t_warm_adapt * 1e6,
        "derived": f"iters={res_warm.iterations};"
                   f"total_s={t_warm_adapt:.3f};"
                   f"new_compiles={warm_compiles};"
                   f"speedup_vs_cold="
                   f"{t_cold_adapt / max(t_warm_adapt, 1e-12):.1f}x;"
                   f"bucket={sess.stats()['bucket']};parity={parity_s}",
    })

    # staged (double-buffered) adapt (PR 5): stage() issues the next
    # snapshot's uploads -- and the per-shape init-op warmup -- ahead of
    # time, so the following adapt() dispatches straight from a
    # device-resident bind with zero new compiles and zero synchronous
    # copies.  Baseline: a synchronous warm adapt of an equally FRESH
    # snapshot (the session_warm_adapt row above is shape-warm because
    # the cold one-shot just ran the identical graph).
    g_sync = add_edges(g_grown, rng.integers(0, v_s, 200),
                       rng.integers(0, v_s, 200), num_vertices=v_s + 12)
    t0 = time.time()
    res_sync = sess.adapt(g_sync, record_history=False)
    t_sync = time.time() - t0
    g_next = add_edges(g_sync, rng.integers(0, v_s, 200),
                       rng.integers(0, v_s, 200), num_vertices=v_s + 14)
    t0 = time.time()
    sess.stage(g_next)
    t_stage = time.time() - t0
    compiles_before = sess.compiles
    t0 = time.time()
    res_staged = sess.adapt(record_history=False)
    t_staged = time.time() - t0
    staged_compiles = sess.compiles - compiles_before
    rows.append({
        "name": "engine/staged_adapt",
        "us_per_call": t_staged * 1e6,
        "derived": f"iters={res_staged.iterations};"
                   f"total_s={t_staged:.3f};stage_s={t_stage:.3f};"
                   f"sync_adapt_s={t_sync:.3f};"
                   f"new_compiles={staged_compiles};"
                   f"speedup_vs_cold="
                   f"{t_cold_adapt / max(t_staged, 1e-12):.1f}x;"
                   f"speedup_vs_sync="
                   f"{t_sync / max(t_staged, 1e-12):.1f}x",
    })
    sess.close()

    # compile cost of the single-dispatch path (first call - steady state)
    labels, loads, key = prepare_init(g, cfg)
    runner = engine.make_fused_runner(g, cfg)
    state0 = engine.init_state(labels, loads, key)
    t0 = time.time()
    jax.block_until_ready(runner(state0))
    t_cold = time.time() - t0
    rows.append({
        "name": "engine/fused_compile",
        "us_per_call": (t_cold - t_fused) * 1e6,
        "derived": f"cold_s={t_cold:.3f};steady_s={t_fused:.3f}",
    })

    emit(rows, "bench_engine")
    return rows


if __name__ == "__main__":
    run()
