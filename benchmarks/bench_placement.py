"""Beyond-paper: Spinner as the placement layer of the LM framework.

(1) MoE expert placement for the two assigned MoE architectures: build a
    synthetic-but-structured router trace (topic-clustered co-activation,
    which mirrors observed expert specialization) and measure the
    cross-EP-shard co-activation mass contiguous vs Spinner.
(2) Pipeline-stage assignment of heterogeneous layer costs.
"""
from __future__ import annotations

import numpy as np

from repro.configs import ARCHS
from repro.core.placement import place_experts, place_pipeline_stages

from .common import emit


def _router_trace(n_experts: int, top_k: int, tokens: int, topics: int,
                  noise: float, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    per = n_experts // topics
    scatter = rng.permutation(n_experts)
    topic = rng.integers(0, topics, tokens)
    pref = scatter[topic[:, None] * per + rng.integers(0, per,
                                                       (tokens, top_k))]
    rand = rng.integers(0, n_experts, (tokens, top_k))
    return np.where(rng.random((tokens, top_k)) < noise, rand, pref
                    ).astype(np.int32)


def run(quick: bool = False) -> list:
    rows = []
    for arch, shards in (("qwen3-moe-235b-a22b", 16),
                         ("kimi-k2-1t-a32b", 16)):
        cfg = ARCHS[arch]
        trace = _router_trace(cfg.n_experts, cfg.top_k,
                              tokens=20_000 if quick else 60_000,
                              topics=shards, noise=0.3, seed=0)
        labels, stats = place_experts(trace, cfg.n_experts, shards, seed=0)
        rows.append({
            "name": f"placement/{arch}/ep{shards}",
            "us_per_call": 0.0,
            "derived": f"cross_contiguous={stats['cross_before']:.3f};"
                       f"cross_spinner={stats['cross_after']:.3f};"
                       f"traffic_reduction={stats['traffic_reduction']:.1%};"
                       f"rho={stats['rho']:.3f};iters={stats['iterations']}",
            **{k: v for k, v in stats.items()},
            "arch": arch,
        })
        # incremental re-placement under routing drift (serving plane)
        drift = _router_trace(cfg.n_experts, cfg.top_k, 20_000, shards,
                              noise=0.45, seed=1)
        labels2, stats2 = place_experts(drift, cfg.n_experts, shards,
                                        seed=1, prev=labels)
        rows.append({
            "name": f"placement/{arch}/incremental",
            "us_per_call": 0.0,
            "derived": f"moved={stats2['moved_from_prev']:.1%};"
                       f"traffic_reduction={stats2['traffic_reduction']:.1%}",
        })
    # pipeline stages: zamba2's heterogeneous blocks (mamba + shared attn)
    costs = np.ones(81)
    costs[5::6] = 2.4   # hybrid layers carry the shared attention block
    labels, st = place_pipeline_stages(costs, 8)
    rows.append({
        "name": "placement/zamba2-7b/pipeline8",
        "us_per_call": 0.0,
        "derived": f"stage_imbalance={st['stage_cost_max_over_mean']:.3f};"
                   f"contiguous={st['contiguous_max_over_mean']:.3f};"
                   f"cuts={st['cut_edges']}(min {st['min_possible_cuts']})",
    })
    emit(rows, "bench_placement")
    return rows


if __name__ == "__main__":
    run()
