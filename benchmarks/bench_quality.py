"""Figure 3 + Tables 1 & 3: locality phi and balance rho vs k.

For each workload graph, sweep the partition count and record phi, rho and
the improvement over hash partitioning (Fig. 3b); the paper's headline
claims are phi comparable to offline partitioners with rho <= c, and
locality improvements over hash growing with k (up to ~250x at k = 512).
"""
from __future__ import annotations

import numpy as np

from repro.core import SpinnerConfig, metrics, partition

from .common import emit, get_graph, hash_labels, timed

SWEEPS = {
    "smallworld-100k": (2, 4, 8, 16, 32, 64, 128, 256, 512),
    "powerlaw-50k": (2, 8, 32, 128),
    "clustered-64k": (2, 8, 32, 64),
}


def run(quick: bool = False) -> list:
    rows = []
    for gname, ks in SWEEPS.items():
        g = get_graph(gname)
        if quick:
            ks = ks[:4]
        for k in ks:
            cfg = SpinnerConfig(k=k, seed=0, max_iters=60 if quick else 120)
            res, dt = timed(partition, g, cfg, record_history=False)
            phi = metrics.phi(g, res.labels)
            rho = metrics.rho(g, res.labels, k)
            phi_hash = metrics.phi(g, hash_labels(g.num_vertices, k))
            rows.append({
                "name": f"quality/{gname}/k{k}",
                "us_per_call": dt * 1e6 / max(1, res.iterations),
                "derived": f"phi={phi:.3f};rho={rho:.3f};"
                           f"phi_over_hash={phi / max(phi_hash, 1e-9):.1f};"
                           f"iters={res.iterations}",
                "phi": phi, "rho": rho, "k": k, "graph": gname,
                "phi_hash": phi_hash, "iterations": res.iterations,
                "seconds": dt,
            })
    emit(rows, "bench_quality")
    return rows


if __name__ == "__main__":
    run()
