"""Figure 4: evolution of phi, rho, score(G) over iterations.

The paper shows (Twitter, hub-heavy): random init is unbalanced
(rho ~ 1.67), balance is recovered within ~20 iterations while phi climbs
steadily, and the halting criterion fires long before the locality
plateau degrades.  Our hub-heavy stand-in is the preferential-attachment
graph.
"""
from __future__ import annotations

from repro.core import SpinnerConfig, partition

from .common import emit, get_graph, timed


def run(quick: bool = False) -> list:
    g = get_graph("powerlaw-50k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=40 if quick else 130)
    # chunked fused engine: per-iteration history recorded on device,
    # one dispatch per 32 iterations instead of per iteration
    res, dt = timed(partition, g, cfg, record_history=True,
                    engine="chunked")
    rows = []
    for h in res.history:
        if h["iteration"] in (1, 2, 3, 5, 8, 13, 21, 34, 55, 89,
                              res.iterations):
            rows.append({
                "name": f"convergence/powerlaw-50k/iter{h['iteration']}",
                "us_per_call": dt * 1e6 / max(1, res.iterations),
                "derived": f"phi={h['phi']:.3f};rho={h['rho']:.3f};"
                           f"score={h['score']:.0f};"
                           f"migrations={h['migrations']}",
                **h,
            })
    rows.append({
        "name": "convergence/powerlaw-50k/halted",
        "us_per_call": dt * 1e6,
        "derived": f"halted_at={res.iterations};"
                   f"initial_rho={res.history[0]['rho']:.3f};"
                   f"final_rho={res.history[-1]['rho']:.3f}",
        "history": res.history,
    })
    emit(rows, "bench_convergence")
    return rows


if __name__ == "__main__":
    run()
