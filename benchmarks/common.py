"""Shared benchmark utilities: graph cache, CSV/JSON emission."""
from __future__ import annotations

import json
import os
import time
from typing import Callable

import numpy as np

ARTIFACTS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                         "artifacts")


_GRAPH_CACHE = {}


def get_graph(name: str):
    """Graphs named in repro.configs.spinner_paper.QUALITY_GRAPHS (cached)."""
    from repro.configs.spinner_paper import QUALITY_GRAPHS
    from repro.core import generators
    if name not in _GRAPH_CACHE:
        gen, kw = QUALITY_GRAPHS[name]
        _GRAPH_CACHE[name] = getattr(generators, gen)(**kw)
    return _GRAPH_CACHE[name]


def emit(rows, artifact_name: str) -> None:
    """Print CSV rows (name,us_per_call,derived) and save the JSON artifact."""
    os.makedirs(ARTIFACTS, exist_ok=True)
    with open(os.path.join(ARTIFACTS, artifact_name + ".json"), "w") as f:
        json.dump(rows, f, indent=1, default=float)
    for r in rows:
        print(f"{r['name']},{r.get('us_per_call', 0):.1f},"
              f"{r.get('derived', '')}", flush=True)


def timed(fn: Callable, *args, repeats: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeats
    return out, dt


def hash_labels(v: int, k: int) -> np.ndarray:
    return (np.arange(v) * np.int64(2654435761) % k).astype(np.int32)
