"""Benchmark orchestrator: one suite per paper table/figure + roofline.

Prints ``name,us_per_call,derived`` CSV.  ``--quick`` shrinks sweeps;
``--suite X`` runs one suite.  Artifacts land in benchmarks/artifacts/.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--suite", default=None,
                    help="quality|convergence|scalability|dynamic|elastic|"
                         "apps|placement|kernel|engine|serve|cluster|"
                         "roofline")
    args = ap.parse_args()

    from . import (bench_apps, bench_convergence, bench_dynamic,
                   bench_elastic, bench_engine, bench_kernel,
                   bench_placement, bench_quality, bench_scalability,
                   bench_serve, roofline)
    suites = {
        "quality": bench_quality.run,          # Fig 3, Tables 1 & 3
        "convergence": bench_convergence.run,  # Fig 4
        "scalability": bench_scalability.run,  # Fig 5
        "dynamic": bench_dynamic.run,          # Fig 6
        "elastic": bench_elastic.run,          # Fig 7
        "apps": bench_apps.run,                # Fig 8, Table 4
        "placement": bench_placement.run,      # beyond-paper
        "kernel": bench_kernel.run,            # Pallas kernel
        "engine": bench_engine.run,            # dispatch/overlap/staged
        "serve": bench_serve.run,              # multi-tenant scheduler
        "cluster": bench_elastic.run_fault,    # fault-injected recovery
        "roofline": roofline.run,              # deliverable (g)
    }
    selected = ([args.suite] if args.suite else list(suites))
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name in selected:
        try:
            rows = suites[name](quick=args.quick)
            if name in ("dynamic", "serve", "cluster", "apps"):
                # perf-trajectory artifacts (delta adapt, serving tier,
                # application speedup): machine-readable, at the repo root
                import json
                import os
                root = os.path.dirname(os.path.dirname(
                    os.path.abspath(__file__)))
                with open(os.path.join(root, f"BENCH_{name}.json"),
                          "w") as fh:
                    json.dump(rows, fh, indent=1, default=float)
        except Exception as e:  # keep the suite running; report at the end
            import traceback
            traceback.print_exc()
            failures.append((name, repr(e)))
    print(f"# total_seconds={time.time() - t0:.1f} failures={failures}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
