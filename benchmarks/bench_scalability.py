"""Figure 5: per-iteration runtime scaling.

(a) vs |V| at fixed degree (Watts-Strogatz, as in the paper),
(b) vs workers (the SHARDED FUSED engine -- one ``shard_map(while_loop)``
    dispatch per run -- in a subprocess with N forced host devices; on
    this 1-core container the numbers validate *overhead*, not speedup;
    see EXPERIMENTS.md),
(c) vs number of partitions k.

For (a)/(c) we time the FIRST full iteration (every vertex active), as in
the paper, averaged over a few repeats after a warmup call.  For (b) we
time a fixed-length fused run (halting disabled) and report the amortized
per-iteration cost, which is exactly what the sharded engine changes.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import SpinnerConfig, generators
from repro.core.spinner import compute_loads, init_labels, make_step

from .common import emit


def _iter_time(g, k: int, repeats: int = 3) -> float:
    cfg = SpinnerConfig(k=k, seed=0)
    step = make_step(g, cfg)
    key = jax.random.PRNGKey(0)
    labels = init_labels(g, cfg, key)
    loads = compute_loads(g, labels, k)
    out = step(labels, loads, key)           # warmup/compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = step(labels, loads, key)       # first-iteration semantics
        jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def run(quick: bool = False) -> list:
    rows = []
    # (a) vs graph size
    sizes = (2**14, 2**15, 2**16) if quick else (2**14, 2**15, 2**16, 2**17)
    for v in sizes:
        g = generators.watts_strogatz(v, 20, 0.3, seed=1)
        dt = _iter_time(g, 16)
        rows.append({
            "name": f"scalability/V{v}",
            "us_per_call": dt * 1e6,
            "derived": f"edges={g.num_undirected_edges};"
                       f"us_per_edge={dt * 1e6 / g.num_undirected_edges:.4f}",
            "V": v, "E": g.num_undirected_edges, "seconds": dt,
        })
    # (c) vs partitions
    g = generators.watts_strogatz(2**15, 20, 0.3, seed=1)
    for k in (2, 8, 32, 128) if quick else (2, 8, 32, 128, 512):
        dt = _iter_time(g, k)
        rows.append({
            "name": f"scalability/k{k}",
            "us_per_call": dt * 1e6,
            "derived": f"us_per_k={dt * 1e6 / k:.2f}",
            "k": k, "seconds": dt,
        })
    # (b) vs workers: the sharded fused engine (ONE while_loop dispatch per
    # run) in a subprocess with forced host device counts.  halt_window >
    # max_iters disables halting so every device count runs the same fixed
    # iteration count and the per-iteration cost is directly comparable.
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    iters = 10 if quick else 20
    for ndev in (1, 2, 4) if quick else (1, 2, 4, 8):
        code = (
            "import time;"
            "from repro.core import generators;"
            "from repro.core.spinner import SpinnerConfig, partition;"
            "from repro.launch.mesh import make_partition_mesh;"
            "g = generators.watts_strogatz(2**15, 20, 0.3, seed=1);"
            f"cfg = SpinnerConfig(k=16, seed=0, max_iters={iters},"
            " halt_window=10**6);"
            "mesh = make_partition_mesh();"
            "kw = dict(record_history=False, engine='sharded', mesh=mesh);"
            "res = partition(g, cfg, **kw);"
            "t0 = time.time();"
            "res = partition(g, cfg, **kw);"
            "print('RUN_S', time.time() - t0, res.iterations)"
        )
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
                   PYTHONPATH=os.path.join(here, "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=600)
        line = [ln for ln in r.stdout.splitlines() if "RUN_S" in ln]
        if line:
            total_s, ran = float(line[0].split()[1]), int(line[0].split()[2])
            dt = total_s / max(1, ran)
        else:
            total_s, ran, dt = float("nan"), 0, float("nan")
        rows.append({
            "name": f"scalability/workers{ndev}",
            "us_per_call": dt * 1e6,
            "derived": f"devices={ndev};iters={ran};"
                       f"run_s={total_s:.3f};engine=sharded",
            "workers": ndev, "seconds": dt,
        })
    emit(rows, "bench_scalability")
    return rows


if __name__ == "__main__":
    run()
