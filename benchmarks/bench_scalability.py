"""Figure 5: per-iteration runtime scaling.

(a) vs |V| at fixed degree (Watts-Strogatz, as in the paper),
(b) vs workers (distributed shard_map engine in a subprocess with N host
    devices -- on this 1-core container the numbers validate *overhead*,
    not speedup; see EXPERIMENTS.md),
(c) vs number of partitions k.

As in the paper we time the FIRST full iteration (every vertex active),
averaged over a few repeats after a warmup call.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.core import SpinnerConfig, generators
from repro.core.spinner import compute_loads, init_labels, make_step

from .common import emit


def _iter_time(g, k: int, repeats: int = 3) -> float:
    cfg = SpinnerConfig(k=k, seed=0)
    step = make_step(g, cfg)
    key = jax.random.PRNGKey(0)
    labels = init_labels(g, cfg, key)
    loads = compute_loads(g, labels, k)
    out = step(labels, loads, key)           # warmup/compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(repeats):
        out = step(labels, loads, key)       # first-iteration semantics
        jax.block_until_ready(out)
    return (time.time() - t0) / repeats


def run(quick: bool = False) -> list:
    rows = []
    # (a) vs graph size
    sizes = (2**14, 2**15, 2**16) if quick else (2**14, 2**15, 2**16, 2**17)
    for v in sizes:
        g = generators.watts_strogatz(v, 20, 0.3, seed=1)
        dt = _iter_time(g, 16)
        rows.append({
            "name": f"scalability/V{v}",
            "us_per_call": dt * 1e6,
            "derived": f"edges={g.num_undirected_edges};"
                       f"us_per_edge={dt * 1e6 / g.num_undirected_edges:.4f}",
            "V": v, "E": g.num_undirected_edges, "seconds": dt,
        })
    # (c) vs partitions
    g = generators.watts_strogatz(2**15, 20, 0.3, seed=1)
    for k in (2, 8, 32, 128) if quick else (2, 8, 32, 128, 512):
        dt = _iter_time(g, k)
        rows.append({
            "name": f"scalability/k{k}",
            "us_per_call": dt * 1e6,
            "derived": f"us_per_k={dt * 1e6 / k:.2f}",
            "k": k, "seconds": dt,
        })
    # (b) vs workers (subprocess with forced host device counts)
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    for ndev in (1, 2, 4) if quick else (1, 2, 4, 8):
        code = (
            "import numpy as np, jax, time;"
            "from repro.core import generators;"
            "from repro.core.spinner import SpinnerConfig;"
            "from repro.core.distributed import shard_graph, "
            "make_distributed_step;"
            "g = generators.watts_strogatz(2**15, 20, 0.3, seed=1);"
            "cfg = SpinnerConfig(k=16, seed=0);"
            f"mesh = jax.sharding.Mesh(np.array(jax.devices()), ('data',));"
            "sg = shard_graph(g, mesh.size);"
            "step = make_distributed_step(sg, cfg, mesh);"
            "import jax.numpy as jnp;"
            "labels = jnp.zeros((sg.ndev, sg.v_per_dev), jnp.int32);"
            "loads = jnp.zeros((16,), jnp.float32)"
            ".at[0].set(float(sg.deg_w.sum()));"
            "args = tuple(map(jnp.asarray, (sg.src_local, sg.dst, sg.weight,"
            " sg.deg_w)));"
            "key = jax.random.PRNGKey(0);"
            "o = step(labels, *args, loads, key); jax.block_until_ready(o);"
            "t0 = time.time();"
            "o = step(labels, *args, loads, key); jax.block_until_ready(o);"
            "print('ITER_S', time.time() - t0)"
        )
        env = dict(os.environ,
                   XLA_FLAGS=f"--xla_force_host_platform_device_count={ndev}",
                   PYTHONPATH=os.path.join(here, "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=600)
        line = [ln for ln in r.stdout.splitlines() if "ITER_S" in ln]
        dt = float(line[0].split()[1]) if line else float("nan")
        rows.append({
            "name": f"scalability/workers{ndev}",
            "us_per_call": dt * 1e6,
            "derived": f"devices={ndev}",
            "workers": ndev, "seconds": dt,
        })
    emit(rows, "bench_scalability")
    return rows


if __name__ == "__main__":
    run()
