"""Figure 6: adapting to dynamic graph changes vs repartitioning from
scratch -- savings in iterations/time/messages (a) and stability (b).

Paper numbers (Tuenti + new-friendship edges): up to 86% time and 92%
message savings at <= 0.5% new edges, >= 80% at larger changes; adaptive
moves only 8-11% of vertices vs 95-98% from scratch.
"""
from __future__ import annotations

import numpy as np

from repro.core import (EngineOptions, SpinnerConfig, adapt, metrics,
                        open_session, partition)
from repro.core.graph import add_edges

from .common import emit, get_graph, timed


def _delta_sweep(quick: bool) -> list:
    """Delta-proportional adapt: warm ``adapt(edge_updates=...)`` latency
    and frontier active-vertex fraction vs a full re-adapt, per |delta|.

    Three sessions walk the same delta stream in lockstep (their labels
    are bit-identical by the parity tests): ``s`` takes the on-device
    fast path, ``f`` reconverges with ``frontier=True``, and ``o`` is
    the classic full re-adapt oracle on the rebuilt host graph.
    """
    g = get_graph("clustered-64k")
    V = g.num_vertices
    cfg = SpinnerConfig(k=32, seed=0, max_iters=60 if quick else 150)
    opts = EngineOptions(engine="fused")
    rng = np.random.default_rng(7)
    sizes = [16, 256] if quick else [16, 256, 4096,
                                     g.num_undirected_edges // 4]
    s = open_session(g, cfg, opts)
    f = open_session(g, cfg, opts)
    o = open_session(g, cfg, opts)
    s.partition(); s.adapt()
    f.partition(); f.adapt()
    o.partition(); o.adapt()
    # one throwaway batch warms the merge/loads/frontier programs so the
    # sweep below measures the steady serving state
    warm = (rng.integers(0, V, 16), rng.integers(0, V, 16))
    s.adapt(edge_updates=warm)
    f.adapt(edge_updates=warm, frontier=True)
    cur = add_edges(g, *warm)
    o.adapt(new_graph=cur)
    rows = []
    for m in sizes:
        batch = (rng.integers(0, V, m), rng.integers(0, V, m))
        before = s.stats()["delta"]["fast_adapts"]
        r_fast, t_fast = timed(s.adapt, edge_updates=batch)
        st = s.stats()["delta"]
        fast_path = st["fast_adapts"] == before + 1
        r_front, t_front = timed(f.adapt, edge_updates=batch,
                                 frontier=True)
        cur = add_edges(cur, *batch)
        r_full, t_full = timed(o.adapt, new_graph=cur)
        active = r_front.scored_vertices / max(1.0, r_front.iterations * V)
        rows.append({
            "name": f"dynamic/delta_{m}",
            "us_per_call": t_fast * 1e6,
            "derived": f"t_full_us={t_full * 1e6:.0f};"
                       f"t_frontier_us={t_front * 1e6:.0f};"
                       f"speedup_vs_full={t_full / max(t_fast, 1e-9):.2f}x;"
                       f"active_fraction={active:.4f};"
                       f"fast_path={fast_path};"
                       f"upload_bytes={st['last_upload_bytes']};"
                       f"iters={r_fast.iterations}v{r_full.iterations}",
            "delta_edges": m,
            "t_fast_us": t_fast * 1e6,
            "t_frontier_us": t_front * 1e6,
            "t_full_us": t_full * 1e6,
            "active_fraction": active,
            "fast_path": fast_path,
            "upload_bytes": st["last_upload_bytes"],
            "frontier_scored_per_iter": list(r_front.scored_per_iter),
            "labels_match_full": bool(
                np.array_equal(r_fast.labels, r_full.labels)),
        })
    return rows


def run(quick: bool = False) -> list:
    g = get_graph("smallworld-100k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=80 if quick else 150)
    # fused engine: a whole (re)partitioning run is one device dispatch
    base, t_base = timed(partition, g, cfg, record_history=False,
                         engine="fused")
    rng = np.random.default_rng(42)
    rows = []
    fracs = (0.001, 0.01) if quick else (0.001, 0.005, 0.01, 0.025, 0.05)
    for frac in fracs:
        m = max(1, int(frac * g.num_undirected_edges))
        g2 = add_edges(g, rng.integers(0, g.num_vertices, m),
                       rng.integers(0, g.num_vertices, m))
        # scratch run must NOT share the base seed, else it retraces the
        # same random trajectory and under-reports the shuffle
        cfg_scr = SpinnerConfig(k=cfg.k, seed=cfg.seed + 1000,
                                max_iters=cfg.max_iters)
        scratch, t_scr = timed(partition, g2, cfg_scr, record_history=False,
                               engine="fused")
        adapted, t_ad = timed(adapt, g2, base.labels, cfg,
                              record_history=False, engine="fused")
        time_saving = 1 - t_ad / t_scr
        iter_saving = 1 - adapted.iterations / max(1, scratch.iterations)
        msg_saving = 1 - adapted.total_messages / max(1.0,
                                                      scratch.total_messages)
        diff_ad = metrics.partitioning_difference(base.labels,
                                                  adapted.labels)
        diff_scr = metrics.partitioning_difference(base.labels,
                                                   scratch.labels)
        rows.append({
            "name": f"dynamic/new_edges_{frac:.3%}",
            "us_per_call": t_ad * 1e6,
            "derived": f"iter_saving={iter_saving:.1%};"
                       f"time_saving={time_saving:.1%};"
                       f"msg_saving={msg_saving:.1%};"
                       f"moved_adaptive={diff_ad:.1%};"
                       f"moved_scratch={diff_scr:.1%};"
                       f"iters={adapted.iterations}v{scratch.iterations};"
                       f"phi={metrics.phi(g2, adapted.labels):.3f};"
                       f"rho={metrics.rho(g2, adapted.labels, 32):.3f}",
            "frac": frac, "time_saving": time_saving,
            "iter_saving": iter_saving,
            "msg_saving": msg_saving, "moved_adaptive": diff_ad,
            "moved_scratch": diff_scr,
            "iters_adaptive": adapted.iterations,
            "iters_scratch": scratch.iterations,
            "phi_adaptive": metrics.phi(g2, adapted.labels),
            "rho_adaptive": metrics.rho(g2, adapted.labels, 32),
        })
    rows.extend(_delta_sweep(quick))
    emit(rows, "bench_dynamic")
    return rows


if __name__ == "__main__":
    run()
