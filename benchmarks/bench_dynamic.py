"""Figure 6: adapting to dynamic graph changes vs repartitioning from
scratch -- savings in iterations/time/messages (a) and stability (b).

Paper numbers (Tuenti + new-friendship edges): up to 86% time and 92%
message savings at <= 0.5% new edges, >= 80% at larger changes; adaptive
moves only 8-11% of vertices vs 95-98% from scratch.
"""
from __future__ import annotations

import numpy as np

from repro.core import SpinnerConfig, adapt, metrics, partition
from repro.core.graph import add_edges

from .common import emit, get_graph, timed


def run(quick: bool = False) -> list:
    g = get_graph("smallworld-100k")
    cfg = SpinnerConfig(k=32, seed=0, max_iters=80 if quick else 150)
    # fused engine: a whole (re)partitioning run is one device dispatch
    base, t_base = timed(partition, g, cfg, record_history=False,
                         engine="fused")
    rng = np.random.default_rng(42)
    rows = []
    fracs = (0.001, 0.01) if quick else (0.001, 0.005, 0.01, 0.025, 0.05)
    for frac in fracs:
        m = max(1, int(frac * g.num_undirected_edges))
        g2 = add_edges(g, rng.integers(0, g.num_vertices, m),
                       rng.integers(0, g.num_vertices, m))
        # scratch run must NOT share the base seed, else it retraces the
        # same random trajectory and under-reports the shuffle
        cfg_scr = SpinnerConfig(k=cfg.k, seed=cfg.seed + 1000,
                                max_iters=cfg.max_iters)
        scratch, t_scr = timed(partition, g2, cfg_scr, record_history=False,
                               engine="fused")
        adapted, t_ad = timed(adapt, g2, base.labels, cfg,
                              record_history=False, engine="fused")
        time_saving = 1 - t_ad / t_scr
        iter_saving = 1 - adapted.iterations / max(1, scratch.iterations)
        msg_saving = 1 - adapted.total_messages / max(1.0,
                                                      scratch.total_messages)
        diff_ad = metrics.partitioning_difference(base.labels,
                                                  adapted.labels)
        diff_scr = metrics.partitioning_difference(base.labels,
                                                   scratch.labels)
        rows.append({
            "name": f"dynamic/new_edges_{frac:.3%}",
            "us_per_call": t_ad * 1e6,
            "derived": f"iter_saving={iter_saving:.1%};"
                       f"time_saving={time_saving:.1%};"
                       f"msg_saving={msg_saving:.1%};"
                       f"moved_adaptive={diff_ad:.1%};"
                       f"moved_scratch={diff_scr:.1%};"
                       f"iters={adapted.iterations}v{scratch.iterations};"
                       f"phi={metrics.phi(g2, adapted.labels):.3f};"
                       f"rho={metrics.rho(g2, adapted.labels, 32):.3f}",
            "frac": frac, "time_saving": time_saving,
            "iter_saving": iter_saving,
            "msg_saving": msg_saving, "moved_adaptive": diff_ad,
            "moved_scratch": diff_scr,
            "iters_adaptive": adapted.iterations,
            "iters_scratch": scratch.iterations,
            "phi_adaptive": metrics.phi(g2, adapted.labels),
            "rho_adaptive": metrics.rho(g2, adapted.labels, 32),
        })
    emit(rows, "bench_dynamic")
    return rows


if __name__ == "__main__":
    run()
